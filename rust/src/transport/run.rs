//! The loopback harness: real worker threads, real sockets, real
//! clocks — driven by the same config surface as the simulator, and
//! feeding its artifacts straight back into it.
//!
//! Per step, each plan-alive worker:
//!
//! 1. **computes** its `M` micro-batches for real (synthetic sleeps:
//!    `(compute_ms + rank·skew_ms)·scale`, with the [`FaultPlan`]'s
//!    slow factors applied on the clock), measuring each duration;
//! 2. reports its arrival offset to the step's **coordinator** (the
//!    lowest plan-alive rank — a pure function of the shared plan, so
//!    no election traffic), which applies the DropCompute membership
//!    rule `arrival ≤ first + deadline` from the installed policy and
//!    broadcasts the survivor set;
//! 3. if a survivor, executes the survivor-subset schedule over the
//!    socket mesh; a peer lost or a deadline blown mid-collective
//!    degrades the step typed instead of hanging.
//!
//! Workers that are plan-dead with a rejoin ahead stay passively
//! synchronized (they wait for each step's membership broadcast);
//! permanently killed workers' threads exit, dropping their sockets so
//! peers observe real EOFs.
//!
//! The run emits a v2 [`TraceRecord`] whose samples are the *measured*
//! wall-clock micro-batch durations (outcomes empty — the acceptance
//! gate is replay-vs-replay: [`replay_bitwise`] checks the compiled
//! and reference timing paths agree bitwise on the recorded draws),
//! plus a [`ConformanceReport`] comparing sim-predicted against
//! measured completion ordering.
//!
//! A note on clocks: arrival offsets are per-worker (each measures
//! from its own step start, as the simulator's common-barrier model
//! does), while ordering conformance uses one shared epoch clock — a
//! persistently excluded worker drifts behind the survivors' cadence,
//! and the gate is exactly the check that this drift never reorders
//! what the model says should be ordered.
//!
//! [`FaultPlan`]: crate::sim::FaultPlan

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::collective::CommError;
use crate::config::Config;
use crate::obs::{ObsRecorder, TransportStats};
use crate::policy::DropPolicy;
use crate::rng::SplitMix64;
use crate::sim::{
    ClusterSim, FaultPlan, StepOutcome, StepTrace, TraceComm, TraceMeta,
    TraceMode, TraceOutcome, TraceRecord, TraceTransport,
    TRACE_FORMAT_VERSION,
};
use crate::topology::{Schedule, TopologyKind};
use crate::util::{Error, Result};

use super::executor::subgroup_all_reduce;
use super::injector::Injector;
use super::peer::{bind_mesh, Endpoint, MeshBinding, SocketMesh};
use super::wire::FrameTag;
use super::{RetryPolicy, TransportKind};

/// Coordinator poll quantum while collecting arrivals.
const POLL: Duration = Duration::from_millis(2);

/// Everything one loopback run needs, decoupled from the config
/// surface so tests can construct it directly.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub workers: usize,
    pub accums: usize,
    pub iters: u64,
    pub kind: TransportKind,
    pub topo: TopologyKind,
    /// Comm-side policy driving the membership deadline. Compute-side
    /// policies (τ, local SGD) are rejected: real workers compute all
    /// `M` micro-batches.
    pub policy: DropPolicy,
    pub plan: Option<FaultPlan>,
    pub retry: RetryPolicy,
    /// Failure-detection bound on every non-membership receive.
    pub recv_deadline: Duration,
    /// Nominal per-micro-batch compute, milliseconds.
    pub compute_ms: f64,
    /// Extra per-micro-batch compute per rank, milliseconds — the
    /// deterministic skew that makes completion ordering predictable.
    pub skew_ms: f64,
    /// Ordering pairs closer than this (predicted, seconds) are not
    /// scored — below it, OS scheduling noise dominates.
    pub min_gap: f64,
    pub grad_len: usize,
    pub seed: u64,
    /// UDS socket directory (`None` = fresh temp dir, removed after).
    pub dir: Option<PathBuf>,
    /// Link parameters recorded into the trace comm model.
    pub latency: f64,
    pub bandwidth: f64,
    pub bytes: f64,
}

impl RunSpec {
    /// Build from the `[transport]`/`[cluster]`/`[policy]`/`[scenario]`
    /// config sections.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let t = &cfg.transport;
        let spec = RunSpec {
            workers: cfg.cluster.workers,
            accums: cfg.cluster.accumulations,
            iters: t.iters as u64,
            kind: t.kind,
            topo: cfg.cluster.topology.unwrap_or(TopologyKind::Ring),
            policy: cfg.effective_policy(),
            plan: cfg.scenario.clone(),
            retry: RetryPolicy {
                attempts: t.connect_attempts as u32,
                backoff_base: Duration::from_secs_f64(t.backoff_base),
                backoff_max: Duration::from_secs_f64(t.backoff_max),
                jitter: t.jitter,
            },
            recv_deadline: Duration::from_secs_f64(t.recv_deadline),
            compute_ms: t.compute_ms,
            skew_ms: t.skew_ms,
            min_gap: t.min_gap,
            grad_len: t.grad_len,
            seed: cfg.train.seed,
            dir: (!t.dir.is_empty()).then(|| PathBuf::from(&t.dir)),
            latency: cfg.cluster.link_latency,
            bandwidth: cfg.cluster.link_bandwidth,
            bytes: cfg.cluster.grad_bytes,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.accums == 0 || self.iters == 0 {
            return Err(Error::Config(
                "transport: workers, accums, and iters must be >= 1".into(),
            ));
        }
        if self.grad_len == 0 {
            return Err(Error::Config("transport: grad_len must be >= 1".into()));
        }
        if !self.policy.comm_only() {
            return Err(Error::Config(format!(
                "transport: policy `{}` has a compute-side component \
                 (tau/local-sgd); real workers compute every micro-batch — \
                 use a comm-side policy (none|deadline|phase-deadline)",
                self.policy.spec()
            )));
        }
        if let Some(plan) = &self.plan {
            plan.validate_for(self.workers)?;
            plan.validate_horizon(self.iters)?;
        }
        if self.compute_ms < 0.0 || self.skew_ms < 0.0 {
            return Err(Error::Config(
                "transport: compute_ms and skew_ms must be >= 0".into(),
            ));
        }
        if !(self.min_gap > 0.0) {
            return Err(Error::Config("transport: min_gap must be > 0".into()));
        }
        Ok(())
    }

    fn comm(&self) -> TraceComm {
        TraceComm::Topology {
            kind: self.topo,
            latency: self.latency,
            bandwidth: self.bandwidth,
            bytes: self.bytes,
        }
    }

    fn transport_meta(&self) -> TraceTransport {
        TraceTransport {
            kind: self.kind,
            recv_deadline: self.recv_deadline.as_secs_f64(),
            connect_attempts: self.retry.attempts,
            backoff_base: self.retry.backoff_base.as_secs_f64(),
            backoff_max: self.retry.backoff_max.as_secs_f64(),
            jitter: self.retry.jitter,
        }
    }
}

/// One step as the driver sees it, merged across workers.
#[derive(Debug, Clone)]
pub struct StepSummary {
    /// Ranks the fault plan had participating.
    pub plan_alive: Vec<usize>,
    /// The survivor set the coordinator chose (sorted global ranks).
    pub members: Vec<usize>,
    /// Per-worker arrival offset from its own step start (NaN = dead).
    pub arrivals: Vec<f64>,
    /// Per-worker arrival instant on the shared epoch clock (NaN = dead).
    pub arrivals_wall: Vec<f64>,
    /// Per-worker collective-completion instant on the epoch clock
    /// (NaN = not a member, degraded, or dead).
    pub completions_wall: Vec<f64>,
    /// Some worker failed typed (peer lost / deadline) after membership.
    pub degraded: bool,
}

/// Sim-vs-real conformance: membership must match exactly; orderings
/// are scored only where the model predicts a gap ≥ `min_gap`.
#[derive(Debug, Clone, Default)]
pub struct ConformanceReport {
    pub steps: usize,
    /// Steps where the coordinator's survivor set differs from the
    /// membership rule applied to the recorded arrivals.
    pub membership_mismatches: usize,
    /// Scored arrival-ordering pairs (compute-completion events).
    pub arrival_pairs: usize,
    pub arrival_agreements: usize,
    /// Scored collective-completion ordering pairs (predicted via the
    /// schedule's readiness recurrence over the recorded arrivals).
    pub completion_pairs: usize,
    pub completion_agreements: usize,
    pub min_gap: f64,
}

impl ConformanceReport {
    pub fn passed(&self) -> bool {
        self.membership_mismatches == 0
            && self.arrival_agreements == self.arrival_pairs
            && self.completion_agreements == self.completion_pairs
    }
}

impl std::fmt::Display for ConformanceReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "steps {}: membership mismatches {}, arrival ordering {}/{}, \
             completion ordering {}/{} (gap >= {}s)",
            self.steps,
            self.membership_mismatches,
            self.arrival_agreements,
            self.arrival_pairs,
            self.completion_agreements,
            self.completion_pairs,
            self.min_gap
        )
    }
}

/// Everything a loopback run produces.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub trace: TraceRecord,
    pub steps: Vec<StepSummary>,
    pub stats: TransportStats,
    pub conformance: ConformanceReport,
}

#[derive(Debug, Clone)]
struct WorkerStepLog {
    samples: Vec<f64>,
    arrival: f64,
    arrival_wall: f64,
    completion_wall: f64,
    members: Vec<usize>,
    degraded: bool,
}

impl WorkerStepLog {
    fn dead() -> Self {
        WorkerStepLog {
            samples: Vec::new(),
            arrival: f64::NAN,
            arrival_wall: f64::NAN,
            completion_wall: f64::NAN,
            members: Vec::new(),
            degraded: false,
        }
    }
}

/// Collect arrivals as this step's coordinator and apply the
/// membership rule. The wall budget is the policy cutoff plus slack
/// for cross-worker step-start drift; peers that die while reporting
/// are simply excluded.
fn coordinate(
    spec: &RunSpec,
    inj: &Injector,
    mesh: &SocketMesh<f32>,
    step: u64,
    step_start: Instant,
    own_arrival: f64,
) -> Vec<usize> {
    let mut got: Vec<(usize, f64)> = vec![(mesh.rank, own_arrival)];
    let mut pending: Vec<usize> = inj
        .alive_set(spec.workers, step)
        .into_iter()
        .filter(|&p| p != mesh.rank)
        .collect();
    loop {
        if pending.is_empty() {
            break;
        }
        let first =
            got.iter().map(|&(_, a)| a).fold(f64::INFINITY, f64::min);
        let budget = match spec.policy.comm_cutoff(0, first) {
            Some(cut) => cut + 0.5 * (cut - first).max(0.0) + 0.02,
            None => spec.recv_deadline.as_secs_f64(),
        };
        if step_start.elapsed().as_secs_f64() >= budget {
            break;
        }
        let mut i = 0;
        while i < pending.len() {
            let src = pending[i];
            match mesh.recv_matching(src, step, 0, FrameTag::Arrive, POLL) {
                Ok(p) => {
                    let a =
                        p.first().map_or(f64::INFINITY, |&v| v as f64);
                    got.push((src, a));
                    pending.swap_remove(i);
                }
                Err(CommError::Timeout { .. }) => i += 1,
                Err(CommError::PeerLost { .. }) => {
                    pending.swap_remove(i);
                }
            }
        }
    }
    let first = got.iter().map(|&(_, a)| a).fold(f64::INFINITY, f64::min);
    let mut members: Vec<usize> = match spec.policy.comm_cutoff(0, first) {
        Some(cut) => got
            .iter()
            .filter(|&&(_, a)| a <= cut)
            .map(|&(r, _)| r)
            .collect(),
        None => got.iter().map(|&(r, _)| r).collect(),
    };
    members.sort_unstable();
    members
}

fn worker_main(
    spec: &RunSpec,
    inj: &Injector,
    binding: MeshBinding,
    endpoints: &[Endpoint],
    epoch: Instant,
) -> Result<(Vec<WorkerStepLog>, TransportStats)> {
    let rank = binding.rank;
    let setup = spec.recv_deadline.max(Duration::from_secs(5));
    let mesh =
        SocketMesh::<f32>::establish(binding, endpoints, spec.retry, setup)?;
    let mut rng = SplitMix64::new(
        spec.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(rank as u64 + 1),
    );
    let mut grad: Vec<f32> = (0..spec.grad_len)
        .map(|i| ((rank + 2) * (i % 13 + 1)) as f32)
        .collect();
    let mut schedules: BTreeMap<usize, Schedule> = BTreeMap::new();
    let mut log: Vec<WorkerStepLog> = Vec::with_capacity(spec.iters as usize);
    let n = spec.workers;
    let nominal_step = Duration::from_secs_f64(
        spec.accums as f64 * spec.compute_ms.max(0.5) / 1000.0,
    );

    for step in 0..spec.iters {
        if !inj.alive(rank, step) {
            if inj.gone_for_good(rank, step) {
                // a real kill: exit, dropping every socket — peers see
                // EOF and get typed PeerLost instead of a hang
                return Ok((log, mesh.take_stats()));
            }
            log.push(WorkerStepLog::dead());
            // stay passively step-synchronized until the rejoin: wait
            // for this step's membership broadcast like everyone else
            match inj.coordinator(n, step) {
                Some(coord) => {
                    let _ = mesh.recv_matching(
                        coord,
                        step,
                        0,
                        FrameTag::Members,
                        spec.recv_deadline,
                    );
                }
                None => thread::sleep(nominal_step),
            }
            continue;
        }

        let step_start = Instant::now();
        let scale = inj.scale(rank, step);
        let mut samples = Vec::with_capacity(spec.accums);
        for _ in 0..spec.accums {
            // deterministic ±5% jitter so draws are not perfectly flat
            let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            let nominal = (spec.compute_ms + spec.skew_ms * rank as f64)
                / 1000.0
                * scale
                * (0.95 + 0.1 * u);
            let t0 = Instant::now();
            thread::sleep(Duration::from_secs_f64(nominal.max(0.0)));
            samples.push(t0.elapsed().as_secs_f64());
        }
        let arrival = step_start.elapsed().as_secs_f64();
        let arrival_wall = epoch.elapsed().as_secs_f64();

        let coord = inj
            .coordinator(n, step)
            .expect("an alive worker implies a coordinator");
        let members = if rank == coord {
            let members =
                coordinate(spec, inj, &mesh, step, step_start, arrival);
            let payload: Vec<f32> =
                members.iter().map(|&r| r as f32).collect();
            for dst in 0..n {
                // every still-connected worker gets the broadcast —
                // including plan-dead-but-rejoining ones, which use it
                // to stay step-synchronized
                if dst != rank && !inj.gone_for_good(dst, step) {
                    let _ = mesh
                        .send(dst, step, 0, FrameTag::Members, &payload);
                }
            }
            members
        } else {
            let _ = mesh.send(
                coord,
                step,
                0,
                FrameTag::Arrive,
                &[arrival as f32],
            );
            match mesh.recv_matching(
                coord,
                step,
                0,
                FrameTag::Members,
                spec.recv_deadline,
            ) {
                Ok(p) => p.iter().map(|&v| v as usize).collect(),
                Err(_) => {
                    // coordinator unreachable: degrade the step typed
                    log.push(WorkerStepLog {
                        samples,
                        arrival,
                        arrival_wall,
                        completion_wall: f64::NAN,
                        members: Vec::new(),
                        degraded: true,
                    });
                    continue;
                }
            }
        };

        let mut completion_wall = f64::NAN;
        let mut degraded = false;
        if members.contains(&rank) {
            let k = members.len();
            let sched = schedules
                .entry(k)
                .or_insert_with(|| spec.topo.build(k));
            let ok = if k >= 2 {
                subgroup_all_reduce(
                    &mesh,
                    &members,
                    sched,
                    step,
                    &mut grad,
                    spec.recv_deadline,
                )
                .is_ok()
            } else {
                true // sole survivor: the reduce is the identity
            };
            if ok {
                completion_wall = epoch.elapsed().as_secs_f64();
            } else {
                degraded = true;
            }
        }
        log.push(WorkerStepLog {
            samples,
            arrival,
            arrival_wall,
            completion_wall,
            members,
            degraded,
        });
    }
    Ok((log, mesh.take_stats()))
}

static RUN_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Execute the full loopback run: bind, spawn, step, join, assemble
/// the trace, score conformance, and (optionally) populate an
/// [`ObsRecorder`] with the run's observability events.
pub fn run_loopback(
    spec: &RunSpec,
    mut obs: Option<&mut ObsRecorder>,
) -> Result<RunReport> {
    spec.validate()?;
    let n = spec.workers;
    let inj = Injector::new(spec.plan.clone(), spec.iters);

    let (dir, ephemeral) = match &spec.dir {
        Some(d) => (d.clone(), false),
        None => (
            std::env::temp_dir().join(format!(
                "dropcompute-run-{}-{}",
                std::process::id(),
                RUN_COUNTER.fetch_add(1, Ordering::Relaxed)
            )),
            true,
        ),
    };
    let (bindings, endpoints) = bind_mesh(spec.kind, n, &dir)?;
    let endpoints = Arc::new(endpoints);
    let spec_arc = Arc::new(spec.clone());
    let inj_arc = Arc::new(inj.clone());
    let epoch = Instant::now();

    let mut handles = Vec::with_capacity(n);
    for binding in bindings {
        let spec = Arc::clone(&spec_arc);
        let inj = Arc::clone(&inj_arc);
        let eps = Arc::clone(&endpoints);
        handles.push(
            thread::Builder::new()
                .name(format!("dc-worker-{}", binding.rank))
                .spawn(move || worker_main(&spec, &inj, binding, &eps, epoch))
                .map_err(|e| {
                    Error::Runtime(format!("transport: spawn worker: {e}"))
                })?,
        );
    }
    let mut logs: Vec<Vec<WorkerStepLog>> = Vec::with_capacity(n);
    let mut stats = TransportStats::default();
    let mut first_err: Option<Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok((log, s))) => {
                stats.merge(&s);
                logs.push(log);
            }
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
                logs.push(Vec::new());
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err =
                        Some(Error::Runtime("transport: worker panicked".into()));
                }
                logs.push(Vec::new());
            }
        }
    }
    if ephemeral {
        let _ = std::fs::remove_dir_all(&dir);
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    // killed workers' logs stop early: pad with dead rows
    for log in &mut logs {
        while (log.len() as u64) < spec.iters {
            log.push(WorkerStepLog::dead());
        }
    }

    // Merge per-worker logs into per-step summaries; the coordinator's
    // membership view is canonical and every live view must agree.
    let mut steps = Vec::with_capacity(spec.iters as usize);
    let mut trace_steps = Vec::with_capacity(spec.iters as usize);
    for step in 0..spec.iters {
        let s = step as usize;
        let plan_alive = inj.alive_set(n, step);
        let coord = inj.coordinator(n, step);
        let members = coord
            .map(|c| logs[c][s].members.clone())
            .unwrap_or_default();
        for &w in &plan_alive {
            let view = &logs[w][s];
            if !view.degraded && view.members != members {
                return Err(Error::Runtime(format!(
                    "transport: step {step}: worker {w} membership view \
                     {:?} disagrees with coordinator {:?}",
                    view.members, members
                )));
            }
        }
        let degraded = plan_alive.iter().any(|&w| logs[w][s].degraded);
        steps.push(StepSummary {
            plan_alive,
            members,
            arrivals: (0..n).map(|w| logs[w][s].arrival).collect(),
            arrivals_wall: (0..n).map(|w| logs[w][s].arrival_wall).collect(),
            completions_wall: (0..n)
                .map(|w| logs[w][s].completion_wall)
                .collect(),
            degraded,
        });
        trace_steps.push(StepTrace {
            straggle: vec![0.0; n],
            samples: (0..n).map(|w| logs[w][s].samples.clone()).collect(),
        });
    }

    let trace = TraceRecord {
        meta: TraceMeta {
            version: TRACE_FORMAT_VERSION,
            mode: TraceMode::Step,
            workers: n,
            accums: spec.accums,
            seed: spec.seed,
            policy: spec.policy.spec(),
            comm: spec.comm(),
            single_restart: false,
            scenario: spec.plan.as_ref().map(|p| p.spec()),
            transport: Some(spec.transport_meta()),
        },
        steps: trace_steps,
        outcomes: Vec::new(),
    };
    trace.validate()?;

    let conformance = conformance(spec, &steps);

    // run-level counters, then the optional recorder
    for s in &steps {
        if s.degraded {
            stats.degraded_steps += 1;
        }
        stats.excluded_arrivals +=
            (s.plan_alive.len() - s.members.len()) as u64;
    }
    if let Some(rec) = obs.as_deref_mut() {
        record_obs(rec, spec, &steps, &stats);
    }

    Ok(RunReport {
        trace,
        steps,
        stats,
        conformance,
    })
}

/// Populate an [`ObsRecorder`] from the run — same semantics as the
/// simulator's observer stream (drops typed per cause, balance held:
/// every scheduled micro-batch is completed or comm-lost).
fn record_obs(
    rec: &mut ObsRecorder,
    spec: &RunSpec,
    steps: &[StepSummary],
    stats: &TransportStats,
) {
    let n = spec.workers;
    let m = spec.accums as u64;
    if rec.workers.len() < n {
        rec.workers.resize(n, Default::default());
    }
    for s in steps {
        rec.steps += 1;
        let mut latest = f64::NEG_INFINITY;
        let mut argmax = None;
        let mut fastest = f64::INFINITY;
        for &w in &s.plan_alive {
            let a = s.arrivals[w];
            if a.is_finite() {
                rec.compute_time.record(a);
                fastest = fastest.min(a);
                if a > latest {
                    latest = a;
                    argmax = Some(w);
                }
            }
        }
        for &w in &s.plan_alive {
            if s.arrivals[w].is_finite() {
                rec.arrival_offset.record(s.arrivals[w] - fastest);
            }
        }
        for w in 0..n {
            if !s.plan_alive.contains(&w) {
                // plan-dead: a worker-fault exclusion event; it computed
                // nothing, so no micro-batches are lost to comm
                rec.drops.worker_fault += 1;
                rec.workers[w].dropped += 1;
                continue;
            }
            rec.workers[w].steps += 1;
            rec.scheduled_microbatches += m;
            if s.members.contains(&w)
                && s.completions_wall[w].is_finite()
            {
                rec.completed_microbatches += m;
            } else {
                // excluded by the membership deadline (or degraded):
                // the computed micro-batches are lost to the comm side
                rec.drops.step_deadline += 1;
                rec.drops.comm_lost_microbatches += m;
                rec.workers[w].dropped += 1;
            }
        }
        if let Some(w) = argmax {
            rec.workers[w].was_max += 1;
        }
        // iter time: earliest live step start to last collective
        // completion, both on the epoch clock
        let begin = s
            .plan_alive
            .iter()
            .map(|&w| s.arrivals_wall[w] - s.arrivals[w])
            .filter(|v| v.is_finite())
            .fold(f64::INFINITY, f64::min);
        let done = s
            .completions_wall
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(f64::NEG_INFINITY, f64::max);
        if begin.is_finite() && done.is_finite() {
            rec.iter_time.record((done - begin).max(1e-9));
        }
    }
    rec.transport.merge(stats);
}

/// Score sim-vs-real conformance (see [`ConformanceReport`]).
pub fn conformance(spec: &RunSpec, steps: &[StepSummary]) -> ConformanceReport {
    let mut rep = ConformanceReport {
        steps: steps.len(),
        min_gap: spec.min_gap,
        ..ConformanceReport::default()
    };
    for s in steps {
        if s.plan_alive.is_empty() {
            continue;
        }
        // membership: the rule on recorded arrivals must reproduce the
        // coordinator's choice exactly
        let arr: Vec<f64> =
            s.plan_alive.iter().map(|&w| s.arrivals[w]).collect();
        let first = arr.iter().cloned().fold(f64::INFINITY, f64::min);
        let predicted: Vec<usize> = match spec.policy.comm_cutoff(0, first) {
            Some(cut) => s
                .plan_alive
                .iter()
                .cloned()
                .filter(|&w| s.arrivals[w] <= cut)
                .collect(),
            None => s.plan_alive.clone(),
        };
        if predicted != s.members {
            rep.membership_mismatches += 1;
        }
        // arrival ordering: per-worker offsets (the sim's common-start
        // model) must order like the shared-epoch wall instants
        score_pairs(
            &arr,
            &s.plan_alive
                .iter()
                .map(|&w| s.arrivals_wall[w])
                .collect::<Vec<_>>(),
            spec.min_gap,
            &mut rep.arrival_pairs,
            &mut rep.arrival_agreements,
        );
        // completion ordering among survivors, where the schedule's
        // readiness recurrence predicts a scoreable gap
        if s.members.len() >= 2 && !s.degraded {
            let marr: Vec<f64> =
                s.members.iter().map(|&w| s.arrivals[w]).collect();
            let sched = spec.topo.build(s.members.len());
            let fin = sched.worker_completion_from(
                &marr,
                spec.latency,
                spec.bandwidth,
                spec.bytes,
            );
            let real: Vec<f64> = s
                .members
                .iter()
                .map(|&w| s.completions_wall[w])
                .collect();
            if real.iter().all(|v| v.is_finite()) {
                score_pairs(
                    &fin,
                    &real,
                    spec.min_gap,
                    &mut rep.completion_pairs,
                    &mut rep.completion_agreements,
                );
            }
        }
    }
    rep
}

fn score_pairs(
    predicted: &[f64],
    real: &[f64],
    min_gap: f64,
    pairs: &mut usize,
    agreements: &mut usize,
) {
    for i in 0..predicted.len() {
        for j in (i + 1)..predicted.len() {
            if !predicted[i].is_finite()
                || !predicted[j].is_finite()
                || !real[i].is_finite()
                || !real[j].is_finite()
                || (predicted[i] - predicted[j]).abs() < min_gap
            {
                continue;
            }
            *pairs += 1;
            if (predicted[i] < predicted[j]) == (real[i] < real[j]) {
                *agreements += 1;
            }
        }
    }
}

/// The bitwise acceptance gate: the recorded trace must replay through
/// [`ClusterSim`] identically on the compiled and reference timing
/// paths (floats compared by bits). Returns the number of steps
/// checked.
pub fn replay_bitwise(trace: &TraceRecord) -> Result<usize> {
    let mut compiled = ClusterSim::from_trace(trace)?;
    let mut reference = ClusterSim::from_trace(trace)?.with_reference_timing();
    let mut a = StepOutcome::default();
    let mut b = StepOutcome::default();
    for step in 0..trace.len() {
        compiled.replay_into(&mut a)?;
        reference.replay_into(&mut b)?;
        if !TraceOutcome::from_outcome(&a).matches(&b) {
            return Err(Error::Runtime(format!(
                "transport: recorded trace diverges between compiled and \
                 reference timing at step {step}"
            )));
        }
    }
    Ok(trace.len())
}
