//! Plan-driven fault injection over *real* worker threads.
//!
//! The same [`FaultPlan`] grammar that drives the simulator's virtual
//! faults here changes what actual threads do:
//!
//! * dead at step `s` with no future rejoin → the worker thread
//!   **returns**, dropping its mesh — peers observe EOF and get typed
//!   [`PeerLost`](crate::collective::CommError) instead of a hang;
//! * dead with a rejoin ahead → the thread idles the step (sockets
//!   stay open) and resynchronizes at the rejoin step;
//! * slowed → the thread's synthetic compute sleeps are stretched by
//!   the plan's scale factor, for real, on the clock.
//!
//! Because the plan is shared, membership coordination needs no
//! failure detector for *planned* deaths: every worker derives the
//! same coordinator (lowest plan-alive rank) and the same wait set per
//! step. Unplanned deaths still degrade typed via socket errors.

use crate::sim::FaultPlan;

/// A [`FaultPlan`] specialized to a concrete run horizon.
#[derive(Debug, Clone)]
pub struct Injector {
    plan: Option<FaultPlan>,
    horizon: u64,
}

impl Injector {
    pub fn new(plan: Option<FaultPlan>, horizon: u64) -> Self {
        Injector { plan, horizon }
    }

    /// Is `worker` scheduled to participate in `step`?
    pub fn alive(&self, worker: usize, step: u64) -> bool {
        self.plan.as_ref().map_or(true, |p| p.alive(worker, step))
    }

    /// Compute-time stretch factor for `worker` at `step`.
    pub fn scale(&self, worker: usize, step: u64) -> f64 {
        self.plan.as_ref().map_or(1.0, |p| p.scale(worker, step))
    }

    /// Dead at `step` and at every remaining step of the run — the
    /// worker thread should exit (a real kill), not idle.
    pub fn gone_for_good(&self, worker: usize, step: u64) -> bool {
        !self.alive(worker, step)
            && (step..self.horizon).all(|s| !self.alive(worker, s))
    }

    /// The membership coordinator for `step`: the lowest plan-alive
    /// rank. A pure function of the shared plan, so every worker
    /// agrees without any election traffic. `None` when the plan has
    /// everyone dead this step.
    pub fn coordinator(&self, workers: usize, step: u64) -> Option<usize> {
        (0..workers).find(|&w| self.alive(w, step))
    }

    /// All plan-alive ranks at `step`, ascending.
    pub fn alive_set(&self, workers: usize, step: u64) -> Vec<usize> {
        (0..workers).filter(|&w| self.alive(w, step)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_rejoin_and_coordinator_handoff() {
        let plan = FaultPlan::parse("kill@2:w0;fail@1:w2,rejoin+2").unwrap();
        let inj = Injector::new(Some(plan), 6);
        // w0 alive for steps 0-1, then permanently gone
        assert!(inj.alive(0, 1));
        assert!(!inj.alive(0, 2));
        assert!(inj.gone_for_good(0, 2));
        assert!(!inj.gone_for_good(0, 1));
        // w2 is down for steps 1-2 but rejoins at 3: not gone for good
        assert!(!inj.alive(2, 1));
        assert!(!inj.gone_for_good(2, 1));
        assert!(inj.alive(2, 3));
        // coordinator hands off from w0 to w1 when w0 dies
        assert_eq!(inj.coordinator(4, 0), Some(0));
        assert_eq!(inj.coordinator(4, 2), Some(1));
        assert_eq!(inj.alive_set(4, 1), vec![0, 1, 3]);
        assert_eq!(inj.alive_set(4, 2), vec![1, 3]);
        // no plan: everyone always alive at scale 1
        let none = Injector::new(None, 6);
        assert!(none.alive(7, 100));
        assert_eq!(none.scale(7, 100), 1.0);
        assert_eq!(none.coordinator(3, 5), Some(0));
    }
}
