//! Schedule execution over the socket mesh — including *survivor
//! subsets*.
//!
//! The discipline is byte-for-byte the one in
//! [`collective::engine`](crate::collective::engine): per phase, ship
//! every outgoing chunk where `t.src == me`, then apply incoming
//! chunks in schedule order (Reduce → `+=`, Copy → overwrite). Fixed
//! application order ⇒ fixed association ⇒ the socket path is
//! bitwise-identical to the mpsc path for the same schedule, which is
//! what the parity suite asserts.
//!
//! The subset form is the DropCompute degradation path: after the
//! membership round agrees on `members` (sorted global ranks), the
//! survivors execute a fresh `k = members.len()` schedule, with
//! schedule rank = index in `members` — the same membership rule the
//! simulator's `SurvivorScheduleCache` models.

use std::ops::AddAssign;
use std::time::Duration;

use crate::collective::CommError;
use crate::topology::{Schedule, TopologyKind, TransferOp};

use super::peer::SocketMesh;
use super::wire::{FrameTag, Wire};

/// Execute `schedule` over the subset `members` of the mesh (sorted
/// global ranks; must contain this rank). Each receive is bounded by
/// `deadline`; a late or dead member surfaces as a typed [`CommError`]
/// so the caller can degrade the step instead of hanging.
pub fn subgroup_all_reduce<T: Wire + AddAssign>(
    mesh: &SocketMesh<T>,
    members: &[usize],
    schedule: &Schedule,
    step: u64,
    buf: &mut [T],
    deadline: Duration,
) -> Result<(), CommError> {
    debug_assert_eq!(schedule.workers, members.len(), "schedule/subset size");
    debug_assert!(schedule.validate().is_ok(), "invalid schedule");
    let me = members
        .iter()
        .position(|&r| r == mesh.rank)
        .expect("subgroup_all_reduce called by a non-member");
    let len = buf.len();
    for (p, phase) in schedule.phases.iter().enumerate() {
        let phase_id = p as u32;
        // 1. ship outgoing chunks (socket buffers absorb them — at most
        //    one chunk per peer per phase, so this does not block).
        for t in &phase.transfers {
            if t.src == me {
                let (a, b) = t.chunk.bounds(len);
                mesh.send(
                    members[t.dst],
                    step,
                    phase_id,
                    FrameTag::Data,
                    &buf[a..b],
                )?;
            }
        }
        // 2. apply incoming chunks in schedule order.
        for t in &phase.transfers {
            if t.dst == me {
                let incoming = mesh.recv_matching(
                    members[t.src],
                    step,
                    phase_id,
                    FrameTag::Data,
                    deadline,
                )?;
                let (a, b) = t.chunk.bounds(len);
                debug_assert_eq!(incoming.len(), b - a, "chunk size");
                match t.op {
                    TransferOp::Reduce => {
                        for (dst, src) in buf[a..b].iter_mut().zip(&incoming)
                        {
                            *dst += *src;
                        }
                    }
                    TransferOp::Copy => {
                        buf[a..b].copy_from_slice(&incoming);
                    }
                }
            }
        }
    }
    Ok(())
}

/// Full-mesh convenience: build `kind`'s schedule for the whole mesh
/// and execute it (step tags the frames; pick a fresh step per op).
pub fn transport_all_reduce<T: Wire + AddAssign>(
    mesh: &SocketMesh<T>,
    kind: TopologyKind,
    step: u64,
    buf: &mut [T],
    deadline: Duration,
) -> Result<(), CommError> {
    let members: Vec<usize> = (0..mesh.size).collect();
    let schedule = kind.build(mesh.size);
    subgroup_all_reduce(mesh, &members, &schedule, step, buf, deadline)
}
