//! Wire format: length-prefixed, little-endian, self-describing frames.
//!
//! Every message on a transport connection is one frame:
//!
//! ```text
//! magic:u32 | src:u32 | step:u32 | phase:u32 | tag:u32 | count:u32 | payload…
//! ```
//!
//! The 24-byte header is fixed; the payload is `count` little-endian
//! elements of the connection's element type (f32 or f64 via [`Wire`]).
//! The `(step, tag, phase)` triple totally orders a connection's
//! frames within the step protocol (ARRIVE → MEMBERS → DATA phases in
//! ascending order), which is what lets receivers *discard* stale
//! frames from excluded-then-resynchronizing peers instead of
//! desynchronizing — see [`Frame::key`].

use std::io::{self, Read, Write};

/// Frame preamble; anything else on the stream is corruption.
pub const MAGIC: u32 = 0xD50C_C0DE;
/// Header size in bytes.
pub const HEADER_BYTES: usize = 24;
/// Upper bound on payload elements — guards allocation against a
/// corrupt or hostile length field.
pub const MAX_FRAME_ELEMS: u32 = 1 << 26;

/// Element types that can cross the wire. Little-endian on the wire
/// regardless of host order; `f32::to_le_bytes`/`from_le_bytes` are
/// bit-exact, so framing never perturbs gradients.
pub trait Wire: Copy + Send + 'static {
    const SIZE: usize;
    fn put(&self, out: &mut Vec<u8>);
    fn get(bytes: &[u8]) -> Self;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
}

impl Wire for f32 {
    const SIZE: usize = 4;
    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn get(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes[..4].try_into().unwrap())
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Wire for f64 {
    const SIZE: usize = 8;
    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn get(bytes: &[u8]) -> Self {
        f64::from_le_bytes(bytes[..8].try_into().unwrap())
    }
    fn from_f64(v: f64) -> Self {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }
}

/// Frame kind, in protocol order *within a step*: arrival report to
/// the coordinator, membership broadcast back, then data phases.
/// `Hello` only appears once per connection, during setup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FrameTag {
    Hello,
    Arrive,
    Members,
    Data,
}

impl FrameTag {
    pub fn code(&self) -> u32 {
        match self {
            FrameTag::Hello => 0,
            FrameTag::Arrive => 1,
            FrameTag::Members => 2,
            FrameTag::Data => 3,
        }
    }

    pub fn from_code(c: u32) -> Option<Self> {
        match c {
            0 => Some(FrameTag::Hello),
            1 => Some(FrameTag::Arrive),
            2 => Some(FrameTag::Members),
            3 => Some(FrameTag::Data),
            _ => None,
        }
    }
}

/// One decoded frame.
#[derive(Debug, Clone)]
pub struct Frame<T> {
    pub src: usize,
    pub step: u64,
    pub phase: u32,
    pub tag: FrameTag,
    pub payload: Vec<T>,
}

impl<T> Frame<T> {
    /// Total protocol order of this frame on its connection: steps
    /// ascend, and within a step ARRIVE < MEMBERS < DATA phases. Stale
    /// frames (smaller key than expected) are safe to drop.
    pub fn key(&self) -> u128 {
        seq_key(self.step, self.tag, self.phase)
    }
}

/// See [`Frame::key`].
pub fn seq_key(step: u64, tag: FrameTag, phase: u32) -> u128 {
    ((step as u128) << 34) | ((tag.code() as u128) << 32) | phase as u128
}

/// Encode and write one frame. A single `write_all` of one contiguous
/// buffer: the per-connection writer lock in
/// [`SocketMesh`](super::SocketMesh) guarantees frames never interleave.
pub fn write_frame<T: Wire>(
    w: &mut impl Write,
    src: usize,
    step: u64,
    phase: u32,
    tag: FrameTag,
    payload: &[T],
) -> io::Result<usize> {
    debug_assert!(step < u32::MAX as u64, "step counter exceeds wire width");
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len() * T::SIZE);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&(src as u32).to_le_bytes());
    buf.extend_from_slice(&(step as u32).to_le_bytes());
    buf.extend_from_slice(&phase.to_le_bytes());
    buf.extend_from_slice(&tag.code().to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for v in payload {
        v.put(&mut buf);
    }
    w.write_all(&buf)?;
    w.flush()?;
    Ok(buf.len())
}

fn header_u32(h: &[u8], idx: usize) -> u32 {
    u32::from_le_bytes(h[idx * 4..idx * 4 + 4].try_into().unwrap())
}

fn corrupt(what: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, what)
}

/// Read and decode one frame (blocking until the connection's read
/// timeout, if any, expires).
pub fn read_frame<T: Wire>(r: &mut impl Read) -> io::Result<Frame<T>> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header)?;
    let magic = header_u32(&header, 0);
    if magic != MAGIC {
        return Err(corrupt(format!(
            "transport: bad frame magic {magic:#010x}"
        )));
    }
    let src = header_u32(&header, 1) as usize;
    let step = header_u32(&header, 2) as u64;
    let phase = header_u32(&header, 3);
    let tag = FrameTag::from_code(header_u32(&header, 4))
        .ok_or_else(|| corrupt("transport: unknown frame tag".into()))?;
    let count = header_u32(&header, 5);
    if count > MAX_FRAME_ELEMS {
        return Err(corrupt(format!(
            "transport: frame claims {count} elements (cap {MAX_FRAME_ELEMS})"
        )));
    }
    let mut bytes = vec![0u8; count as usize * T::SIZE];
    r.read_exact(&mut bytes)?;
    let payload = bytes.chunks_exact(T::SIZE).map(T::get).collect();
    Ok(Frame {
        src,
        step,
        phase,
        tag,
        payload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip<T: Wire + PartialEq + std::fmt::Debug>(payload: &[T]) {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, 3, 17, 2, FrameTag::Data, payload)
            .unwrap();
        assert_eq!(n, HEADER_BYTES + payload.len() * T::SIZE);
        let f: Frame<T> = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f.src, 3);
        assert_eq!(f.step, 17);
        assert_eq!(f.phase, 2);
        assert_eq!(f.tag, FrameTag::Data);
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn frames_round_trip_bit_exact() {
        round_trip::<f32>(&[1.5, -0.0, f32::MIN_POSITIVE, 3.0e-39]);
        round_trip::<f64>(&[std::f64::consts::PI, -1.0e-300, 0.0]);
        round_trip::<f32>(&[]);
        // NaN payloads survive with their exact bit pattern
        let mut buf = Vec::new();
        write_frame(&mut buf, 0, 0, 0, FrameTag::Arrive, &[f32::NAN])
            .unwrap();
        let f: Frame<f32> = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f.payload[0].to_bits(), f32::NAN.to_bits());
    }

    #[test]
    fn corruption_is_a_typed_io_error_not_a_panic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 2, 0, FrameTag::Data, &[1.0f32, 2.0])
            .unwrap();
        // bad magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        let e = read_frame::<f32>(&mut Cursor::new(&bad)).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        // unknown tag
        let mut bad = buf.clone();
        bad[16] = 99;
        assert!(read_frame::<f32>(&mut Cursor::new(&bad)).is_err());
        // absurd length field must not allocate; it must error
        let mut bad = buf.clone();
        bad[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame::<f32>(&mut Cursor::new(&bad)).is_err());
        // truncated payload
        let e = read_frame::<f32>(&mut Cursor::new(&buf[..buf.len() - 2]))
            .unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn seq_key_orders_the_step_protocol() {
        let arrive = seq_key(5, FrameTag::Arrive, 0);
        let members = seq_key(5, FrameTag::Members, 0);
        let d0 = seq_key(5, FrameTag::Data, 0);
        let d1 = seq_key(5, FrameTag::Data, 1);
        let next = seq_key(6, FrameTag::Arrive, 0);
        assert!(arrive < members && members < d0 && d0 < d1 && d1 < next);
    }
}
