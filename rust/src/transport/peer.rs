//! The socket mesh: n fully-connected peers, one *unidirectional*
//! connection per ordered pair. Rank `a` dials rank `b`'s listener and
//! only ever writes on that connection; `b` accepts it and only reads.
//! Every inbound connection gets a reader thread that decodes frames
//! into a per-source mpsc channel, which makes the receive side
//! *exactly* the [`MeshComm`](crate::collective::MeshComm) contract:
//!
//! * peer closes or dies → reader sees EOF/reset → sender dropped →
//!   `recv` returns [`CommError::PeerLost`];
//! * deadline expires with the channel empty →
//!   [`CommError::Timeout`] naming the peer and the wait.
//!
//! Because both meshes speak the same [`CommError`] vocabulary, the
//! schedule executor and every degradation path above it are shared
//! between the simulated and real transports.
//!
//! Setup cannot deadlock: all listeners are bound (in [`bind_mesh`])
//! before any worker dials, and `connect(2)` against a bound listener
//! succeeds from the OS backlog without an `accept(2)` — so every rank
//! may dial all its outbound connections first and accept inbound
//! afterwards, for any mesh ≤ the OS backlog (≫ any loopback run).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use crate::collective::CommError;
use crate::obs::TransportStats;
use crate::rng::SplitMix64;
use crate::util::{Error, Result};

use super::wire::{read_frame, write_frame, seq_key, Frame, FrameTag, Wire};
use super::{RetryPolicy, TransportKind};

/// Where a peer's listener can be dialed.
#[derive(Debug, Clone)]
pub enum Endpoint {
    Uds(PathBuf),
    Tcp(SocketAddr),
}

enum Listener {
    Uds(UnixListener),
    Tcp(TcpListener),
}

enum Conn {
    Uds(UnixStream),
    Tcp(TcpStream),
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Uds(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Uds(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Uds(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

impl Conn {
    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Uds(s) => s.set_read_timeout(d),
            Conn::Tcp(s) => s.set_read_timeout(d),
        }
    }
}

fn io_err(ctx: &str, e: io::Error) -> Error {
    Error::Io(format!("transport: {ctx}: {e}"))
}

/// A bound, not-yet-connected listener for one rank.
pub struct MeshBinding {
    pub rank: usize,
    listener: Listener,
}

/// Bind one listener per rank up front (UDS sockets under `dir`, or
/// TCP on 127.0.0.1 with OS-assigned ports) and return the bindings
/// plus the endpoint table every rank needs to dial the others.
pub fn bind_mesh(
    kind: TransportKind,
    n: usize,
    dir: &Path,
) -> Result<(Vec<MeshBinding>, Vec<Endpoint>)> {
    let mut bindings = Vec::with_capacity(n);
    let mut endpoints = Vec::with_capacity(n);
    if kind == TransportKind::Uds {
        std::fs::create_dir_all(dir)
            .map_err(|e| io_err(&format!("mkdir {}", dir.display()), e))?;
    }
    for rank in 0..n {
        match kind {
            TransportKind::Uds => {
                let path = dir.join(format!("w{rank}.sock"));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .map_err(|e| io_err(&format!("bind {}", path.display()), e))?;
                l.set_nonblocking(true)
                    .map_err(|e| io_err("listener nonblocking", e))?;
                bindings.push(MeshBinding {
                    rank,
                    listener: Listener::Uds(l),
                });
                endpoints.push(Endpoint::Uds(path));
            }
            TransportKind::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0")
                    .map_err(|e| io_err("bind 127.0.0.1:0", e))?;
                let addr =
                    l.local_addr().map_err(|e| io_err("local_addr", e))?;
                l.set_nonblocking(true)
                    .map_err(|e| io_err("listener nonblocking", e))?;
                bindings.push(MeshBinding {
                    rank,
                    listener: Listener::Tcp(l),
                });
                endpoints.push(Endpoint::Tcp(addr));
            }
        }
    }
    Ok((bindings, endpoints))
}

fn dial(ep: &Endpoint) -> io::Result<Conn> {
    match ep {
        Endpoint::Uds(path) => UnixStream::connect(path).map(Conn::Uds),
        Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Conn::Tcp),
    }
}

fn accept_one(listener: &Listener) -> io::Result<Conn> {
    match listener {
        Listener::Uds(l) => l.accept().map(|(s, _)| Conn::Uds(s)),
        Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
    }
}

/// Errors that mean the connection is gone for good — retrying a send
/// cannot help (and the stream may already be desynchronized).
fn fatal_io(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::BrokenPipe
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::NotConnected
    )
}

fn reader_loop<T: Wire>(mut conn: Conn, tx: Sender<Frame<T>>) {
    loop {
        match read_frame::<T>(&mut conn) {
            Ok(f) => {
                if tx.send(f).is_err() {
                    return; // receiver gone: mesh dropped
                }
            }
            // EOF, reset, or corruption: dropping `tx` is the signal —
            // the owner sees Disconnected and maps it to PeerLost.
            Err(_) => return,
        }
    }
}

/// One rank's view of the fully-connected socket mesh.
pub struct SocketMesh<T: Wire> {
    pub rank: usize,
    pub size: usize,
    retry: RetryPolicy,
    writers: Vec<Option<Mutex<Conn>>>,
    from: Vec<Option<Receiver<Frame<T>>>>,
    stats: Mutex<TransportStats>,
    rng: Mutex<SplitMix64>,
}

impl<T: Wire> SocketMesh<T> {
    /// Dial every peer (with bounded, jittered retry), announce
    /// ourselves with a HELLO frame, then accept and identify every
    /// inbound connection. Must run concurrently on all ranks; a peer
    /// that never shows up fails the setup typed after `setup_timeout`.
    pub fn establish(
        binding: MeshBinding,
        endpoints: &[Endpoint],
        retry: RetryPolicy,
        setup_timeout: Duration,
    ) -> Result<Self> {
        let n = endpoints.len();
        let rank = binding.rank;
        let mut stats = TransportStats::default();
        let mut rng = SplitMix64::new(0xD50C_0000 ^ rank as u64);

        // Outbound: dial + HELLO toward every peer.
        let mut writers: Vec<Option<Mutex<Conn>>> = Vec::with_capacity(n);
        for dst in 0..n {
            if dst == rank {
                writers.push(None);
                continue;
            }
            let mut attempt = 0u32;
            let conn = loop {
                match dial(&endpoints[dst]) {
                    Ok(c) => break c,
                    Err(e) => {
                        attempt += 1;
                        if attempt >= retry.attempts.max(1) {
                            return Err(io_err(
                                &format!("rank {rank}: dial peer {dst}"),
                                e,
                            ));
                        }
                        stats.connect_retries += 1;
                        let d = retry.delay(attempt - 1, &mut rng);
                        stats.backoff_wait.record(d.as_secs_f64());
                        thread::sleep(d);
                    }
                }
            };
            let mut conn = conn;
            let sent =
                write_frame::<T>(&mut conn, rank, 0, 0, FrameTag::Hello, &[])
                    .map_err(|e| {
                        io_err(&format!("rank {rank}: hello to {dst}"), e)
                    })?;
            stats.frames_sent += 1;
            stats.bytes_sent += sent as u64;
            writers.push(Some(Mutex::new(conn)));
        }

        // Inbound: accept n-1 connections, identify each by its HELLO.
        let mut senders: Vec<Option<Sender<Frame<T>>>> =
            (0..n).map(|_| None).collect();
        let mut from: Vec<Option<Receiver<Frame<T>>>> =
            (0..n).map(|_| None).collect();
        for src in 0..n {
            if src == rank {
                continue;
            }
            let (tx, rx) = channel();
            senders[src] = Some(tx);
            from[src] = Some(rx);
        }
        let deadline = Instant::now() + setup_timeout;
        let mut accepted = 0usize;
        while accepted < n.saturating_sub(1) {
            if Instant::now() >= deadline {
                return Err(Error::Runtime(format!(
                    "transport: rank {rank}: only {accepted}/{} peers \
                     connected within {:.1}s",
                    n - 1,
                    setup_timeout.as_secs_f64()
                )));
            }
            let conn = match accept_one(&binding.listener) {
                Ok(c) => c,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(1));
                    continue;
                }
                Err(e) => {
                    return Err(io_err(&format!("rank {rank}: accept"), e))
                }
            };
            conn.set_nonblocking_off()
                .map_err(|e| io_err("accepted conn blocking", e))?;
            conn.set_read_timeout(Some(
                deadline.saturating_duration_since(Instant::now())
                    .max(Duration::from_millis(10)),
            ))
            .map_err(|e| io_err("hello read timeout", e))?;
            let mut conn = conn;
            let hello = read_frame::<T>(&mut conn)
                .map_err(|e| io_err(&format!("rank {rank}: read hello"), e))?;
            if hello.tag != FrameTag::Hello
                || hello.src >= n
                || hello.src == rank
            {
                return Err(Error::Runtime(format!(
                    "transport: rank {rank}: bad hello (tag {:?}, src {})",
                    hello.tag, hello.src
                )));
            }
            let tx = senders[hello.src].take().ok_or_else(|| {
                Error::Runtime(format!(
                    "transport: rank {rank}: duplicate hello from {}",
                    hello.src
                ))
            })?;
            conn.set_read_timeout(None)
                .map_err(|e| io_err("clear read timeout", e))?;
            thread::Builder::new()
                .name(format!("dc-rx-{rank}-from-{}", hello.src))
                .spawn(move || reader_loop(conn, tx))
                .map_err(|e| io_err("spawn reader", e))?;
            accepted += 1;
        }

        Ok(SocketMesh {
            rank,
            size: n,
            retry,
            writers,
            from,
            stats: Mutex::new(stats),
            rng: Mutex::new(rng),
        })
    }

    fn with_stats<R>(&self, f: impl FnOnce(&mut TransportStats) -> R) -> R {
        let mut g = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut g)
    }

    /// Drain the mesh's transport counters (merge rank-by-rank for a
    /// deterministic run total).
    pub fn take_stats(&self) -> TransportStats {
        self.with_stats(std::mem::take)
    }

    /// Send one frame to `dst`, retrying transient I/O failures with
    /// the mesh's backoff policy. Fatal socket errors (peer closed,
    /// reset) short-circuit to [`CommError::PeerLost`] — retrying a
    /// half-dead stream could interleave a partial frame.
    pub fn send(
        &self,
        dst: usize,
        step: u64,
        phase: u32,
        tag: FrameTag,
        payload: &[T],
    ) -> std::result::Result<(), CommError> {
        assert_ne!(dst, self.rank, "transport: self-send");
        let slot = self.writers[dst]
            .as_ref()
            .expect("writer table covers every peer");
        // lint:allow(lock-across-io): frame atomicity — a retried send must not interleave a partial frame
        let mut conn = slot.lock().unwrap_or_else(|p| p.into_inner());
        let mut attempt = 0u32;
        loop {
            match write_frame(&mut *conn, self.rank, step, phase, tag, payload)
            {
                Ok(sent) => {
                    self.with_stats(|s| {
                        s.frames_sent += 1;
                        s.bytes_sent += sent as u64;
                    });
                    return Ok(());
                }
                Err(e) if fatal_io(&e) => {
                    self.with_stats(|s| s.peers_lost += 1);
                    return Err(CommError::PeerLost { peer: dst });
                }
                Err(_) => {
                    attempt += 1;
                    if attempt >= self.retry.attempts.max(1) {
                        self.with_stats(|s| s.peers_lost += 1);
                        return Err(CommError::PeerLost { peer: dst });
                    }
                    let d = {
                        let mut rng =
                            self.rng.lock().unwrap_or_else(|p| p.into_inner());
                        self.retry.delay(attempt - 1, &mut rng)
                    };
                    self.with_stats(|s| {
                        s.send_retries += 1;
                        s.backoff_wait.record(d.as_secs_f64());
                    });
                    thread::sleep(d);
                }
            }
        }
    }

    /// Receive the next frame from `src`, waiting at most `timeout`.
    /// Mirrors [`MeshComm::recv_deadline`](crate::collective::MeshComm):
    /// a dead peer is `PeerLost`, an expired deadline is `Timeout`.
    pub fn recv_deadline(
        &self,
        src: usize,
        timeout: Duration,
    ) -> std::result::Result<Frame<T>, CommError> {
        assert_ne!(src, self.rank, "transport: self-recv");
        let rx = self.from[src]
            .as_ref()
            .expect("receiver table covers every peer");
        let t0 = Instant::now();
        let out = match rx.recv_timeout(timeout) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Disconnected) => {
                self.with_stats(|s| s.peers_lost += 1);
                Err(CommError::PeerLost { peer: src })
            }
            Err(RecvTimeoutError::Timeout) => {
                self.with_stats(|s| s.recv_timeouts += 1);
                Err(CommError::Timeout {
                    peer: src,
                    waited: timeout,
                })
            }
        };
        self.with_stats(|s| s.recv_wait.record(t0.elapsed().as_secs_f64()));
        out
    }

    /// Receive the frame matching `(step, tag, phase)` from `src`,
    /// *discarding* any stale frames first — leftovers from steps or
    /// phases a previously excluded/degraded peer sent before
    /// resynchronizing. A frame from the *future* means this worker
    /// itself fell behind the protocol; that surfaces as `Timeout`.
    pub fn recv_matching(
        &self,
        src: usize,
        step: u64,
        phase: u32,
        tag: FrameTag,
        timeout: Duration,
    ) -> std::result::Result<Vec<T>, CommError> {
        let want = seq_key(step, tag, phase);
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let f = self.recv_deadline(src, remaining)?;
            let key = f.key();
            if key < want {
                continue; // stale: excluded peer catching up
            }
            if key == want {
                return Ok(f.payload);
            }
            return Err(CommError::Timeout {
                peer: src,
                waited: timeout,
            });
        }
    }
}

impl Conn {
    /// Accepted sockets may or may not inherit the listener's
    /// nonblocking flag depending on platform; force blocking mode.
    fn set_nonblocking_off(&self) -> io::Result<()> {
        match self {
            Conn::Uds(s) => s.set_nonblocking(false),
            Conn::Tcp(s) => s.set_nonblocking(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("dropcompute-peer-{}-{tag}", std::process::id()))
    }

    /// Establish an n-rank mesh concurrently and hand each to `body`.
    fn with_mesh<F>(kind: TransportKind, n: usize, tag: &str, body: F)
    where
        F: Fn(SocketMesh<f32>) + Send + Sync + 'static + Clone,
    {
        let dir = scratch(tag);
        let (bindings, endpoints) = bind_mesh(kind, n, &dir).unwrap();
        let endpoints = std::sync::Arc::new(endpoints);
        let mut handles = Vec::new();
        for b in bindings {
            let eps = endpoints.clone();
            let body = body.clone();
            handles.push(thread::spawn(move || {
                let mesh = SocketMesh::<f32>::establish(
                    b,
                    &eps,
                    RetryPolicy::default(),
                    Duration::from_secs(10),
                )
                .unwrap();
                body(mesh);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uds_pair_exchanges_frames_bit_exact() {
        with_mesh(TransportKind::Uds, 2, "pair", |mesh| {
            let other = 1 - mesh.rank;
            let payload = vec![mesh.rank as f32 + 0.25, -1.5e-7];
            mesh.send(other, 3, 1, FrameTag::Data, &payload).unwrap();
            let got = mesh
                .recv_matching(
                    other,
                    3,
                    1,
                    FrameTag::Data,
                    Duration::from_secs(5),
                )
                .unwrap();
            assert_eq!(got.len(), 2);
            assert_eq!(got[0].to_bits(), (other as f32 + 0.25).to_bits());
            assert_eq!(got[1].to_bits(), (-1.5e-7f32).to_bits());
        });
    }

    #[test]
    fn tcp_mesh_works_too_and_discards_stale_frames() {
        with_mesh(TransportKind::Tcp, 2, "tcp", |mesh| {
            let other = 1 - mesh.rank;
            // a stale step-0 frame followed by the wanted step-1 frame
            mesh.send(other, 0, 0, FrameTag::Data, &[9.0]).unwrap();
            mesh.send(other, 1, 0, FrameTag::Data, &[42.0]).unwrap();
            let got = mesh
                .recv_matching(
                    other,
                    1,
                    0,
                    FrameTag::Data,
                    Duration::from_secs(5),
                )
                .unwrap();
            assert_eq!(got, vec![42.0]);
        });
    }

    #[test]
    fn dead_peer_is_typed_peer_lost_and_timeout_names_the_peer() {
        with_mesh(TransportKind::Uds, 2, "dead", |mesh| {
            if mesh.rank == 0 {
                // rank 1 exits immediately; our reader sees EOF.
                let err = mesh
                    .recv_deadline(1, Duration::from_secs(5))
                    .unwrap_err();
                assert_eq!(err, CommError::PeerLost { peer: 1 });
                assert!(mesh.take_stats().peers_lost >= 1);
            }
            // rank 1: drop the mesh right away (sockets close)
        });
        // Timeout: peer alive but silent.
        with_mesh(TransportKind::Uds, 2, "slow", |mesh| {
            if mesh.rank == 0 {
                let err = mesh
                    .recv_deadline(1, Duration::from_millis(30))
                    .unwrap_err();
                match err {
                    CommError::Timeout { peer, waited } => {
                        assert_eq!(peer, 1);
                        assert_eq!(waited, Duration::from_millis(30));
                    }
                    other => panic!("want timeout, got {other}"),
                }
                assert!(mesh.take_stats().recv_timeouts >= 1);
                // unblock rank 1
                mesh.send(1, 0, 0, FrameTag::Data, &[1.0]).unwrap();
            } else {
                mesh.recv_matching(
                    0,
                    0,
                    0,
                    FrameTag::Data,
                    Duration::from_secs(5),
                )
                .unwrap();
            }
        });
    }
}
