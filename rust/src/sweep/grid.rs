//! Scenario grids: the `(workers × threshold × deadline × seed)`
//! cartesian product — or, with [`SweepSpec::policies`], the
//! `(workers × policy × seed)` product over arbitrary
//! [`DropPolicy`]s — its fixed serial enumeration order, and the
//! per-point measurement.

use std::sync::Arc;

use crate::config::ClusterConfig;
use crate::obs::{NoopObserver, ObsRecorder, SimObserver};
use crate::policy::DropPolicy;
use crate::rng::SplitMix64;
use crate::sim::{
    ClusterSim, FaultPlan, ReplicaBatch, StepOutcome, TraceRecord,
};

use super::cache::SurvivorCachePool;
use super::runner::run_indexed;

/// Domain-separation constant mixed into every per-point sim seed so
/// sweep streams never collide with the coordinator's `seed ^ k`
/// derivations.
const SEED_DOMAIN: u64 = 0x5EED_0F5A_CE11_DA7A;

/// A full scenario grid: every combination of the four axes is one
/// point. Axes with a single entry are effectively pinned, so the same
/// type expresses a 1-D threshold sweep and a million-point 4-D grid.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Base cluster; `workers` / `comm_drop_deadline` are overridden
    /// per point.
    pub base: ClusterConfig,
    /// Cluster sizes `N`.
    pub workers: Vec<usize>,
    /// DropCompute thresholds `tau` (0.0 = DropCompute off). Ignored
    /// when [`Self::policies`] is set.
    pub thresholds: Vec<f64>,
    /// DropComm bounded-wait deadlines (0.0 = wait for everyone).
    /// Ignored when [`Self::policies`] is set.
    pub deadlines: Vec<f64>,
    /// Policy axis: when non-empty the grid is
    /// `workers × policies × seeds` and each point steps under its
    /// [`DropPolicy`] — subsuming the `thresholds`/`deadlines`/`period`
    /// axes (a legacy `(tau, deadline)` point is the policy
    /// `tau=T+deadline=D`; bitwise identical, property-tested) and
    /// adding what they cannot express: per-phase deadlines, preemption
    /// variants, Local-SGD arms, compositions — all in one axis.
    pub policies: Vec<DropPolicy>,
    /// Scenario axis: when non-empty every point also runs under one
    /// [`FaultPlan`] (the churn ablation — an empty plan is the
    /// fault-free arm). Events naming workers beyond a point's cluster
    /// are inert by design, so one plan spans a whole workers axis.
    pub scenarios: Vec<FaultPlan>,
    /// Seed axis. The same seed value across other axes gives paired
    /// (common-random-number) comparisons between arms.
    pub seeds: Vec<u64>,
    /// Replay axis: when set, every point replays this recorded trace
    /// ([`ClusterSim::from_trace`]) under the point's policy instead of
    /// sampling synthetic noise — recorded reality as a grid dimension
    /// alongside the synthetic ones. [`Self::replay`] pins the workers
    /// axis to the trace's shape and clamps `iters` to its length;
    /// replay is deterministic, so the seed axis leaves replay points
    /// unchanged (a useful cross-check). Policies whose mode (step vs
    /// local-sgd) contradicts the trace are a programmer error and
    /// panic with a clear message.
    pub replay: Option<Arc<TraceRecord>>,
    /// Measured iterations per point.
    pub iters: usize,
    /// Local-SGD synchronization period H: 1 (default) measures
    /// synchronous steps ([`ClusterSim::step_into`]); H > 1 measures
    /// Local-SGD periods of H local steps each
    /// ([`ClusterSim::local_sgd_period_into`], one micro-batch per
    /// local step, thresholds applied per local step).
    pub period: usize,
    /// Worker threads (0 = all cores, 1 = serial).
    pub jobs: usize,
    /// Seed-axis batch width: `batch > 1` advances up to that many
    /// consecutive seed-coordinate points per pass through one
    /// [`crate::sim::ReplicaBatch`] SoA lockstep step (seeds are the
    /// fastest-varying axis, so consecutive indices share every other
    /// coordinate). Results are bitwise independent of the width —
    /// batched == scalar per replica, property-tested in
    /// `tests/batch_equivalence.rs`. 0/1 = scalar per-point stepping.
    pub batch: usize,
    /// Report progress/ETA to stderr while running.
    pub progress: bool,
}

/// Coordinates of one grid point. On the policy axis
/// ([`SweepSpec::policies`]) `policy` is set and `threshold`/`deadline`
/// carry its resolved compute/step-deadline values for display.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepParams {
    pub workers: usize,
    pub threshold: f64,
    pub deadline: f64,
    pub seed: u64,
    pub policy: Option<DropPolicy>,
    /// The point's fault plan (scenario-axis sweeps only).
    pub scenario: Option<FaultPlan>,
}

/// Measured outcome of one grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Position in the serial enumeration order.
    pub index: usize,
    pub workers: usize,
    pub threshold: f64,
    pub deadline: f64,
    pub seed: u64,
    /// Spec string of the point's [`DropPolicy`] (policy-axis sweeps
    /// only; `None` on the legacy axes).
    pub policy: Option<String>,
    /// Spec string of the point's [`FaultPlan`] (scenario-axis sweeps
    /// only).
    pub scenario: Option<String>,
    pub mean_iter_time: f64,
    pub mean_compute_time: f64,
    /// Useful micro-batches per second (dropped work excluded).
    pub throughput: f64,
    pub drop_rate: f64,
}

/// All points of a completed sweep, in serial enumeration order.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
}

impl SweepSpec {
    /// A one-point spec around `base` (sweep the builder methods open).
    pub fn new(base: ClusterConfig) -> Self {
        let workers = vec![base.workers];
        let deadlines = vec![base.comm_drop_deadline];
        Self {
            base,
            workers,
            thresholds: vec![0.0],
            deadlines,
            policies: Vec::new(),
            scenarios: Vec::new(),
            seeds: vec![0],
            replay: None,
            iters: 50,
            period: 1,
            jobs: 0,
            batch: 1,
            progress: false,
        }
    }

    /// Replay `trace` at every grid point instead of sampling synthetic
    /// noise (see the field docs): the workers axis becomes the trace's
    /// worker count and `iters` is clamped to the recorded length.
    pub fn replay(mut self, trace: TraceRecord) -> Self {
        self.workers = vec![trace.meta.workers];
        self.iters = self.iters.min(trace.len().max(1));
        self.replay = Some(Arc::new(trace));
        self
    }

    /// Sweep [`DropPolicy`]s instead of the `thresholds × deadlines`
    /// product (see the field docs). The grid becomes
    /// `workers × policies × seeds`.
    pub fn policies(mut self, policies: &[DropPolicy]) -> Self {
        self.policies = policies.to_vec();
        self
    }

    pub fn workers(mut self, ns: &[usize]) -> Self {
        self.workers = ns.iter().map(|&n| n.max(1)).collect();
        self
    }

    pub fn thresholds(mut self, taus: &[f64]) -> Self {
        self.thresholds = taus.to_vec();
        self
    }

    pub fn deadlines(mut self, ds: &[f64]) -> Self {
        self.deadlines = ds.to_vec();
        self
    }

    /// Sweep [`FaultPlan`]s: every point also runs under each plan
    /// (see the field docs). An empty plan is the fault-free arm.
    pub fn scenarios(mut self, plans: &[FaultPlan]) -> Self {
        self.scenarios = plans.to_vec();
        self
    }

    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters.max(1);
        self
    }

    /// Measure Local-SGD periods of `h` local steps instead of
    /// synchronous steps (`h = 1` is the synchronous default).
    pub fn period(mut self, h: usize) -> Self {
        self.period = h.max(1);
        self
    }

    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Step up to `batch` seed-adjacent points per lockstep pass (see
    /// the field docs); 0 and 1 both mean scalar per-point stepping.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    pub fn progress(mut self, on: bool) -> Self {
        self.progress = on;
        self
    }

    /// Number of grid points: `workers × thresholds × deadlines × seeds`
    /// on the legacy axes, `workers × policies × seeds` on the policy
    /// axis; a non-empty scenario axis multiplies either product.
    pub fn len(&self) -> usize {
        let scenarios = self.scenarios.len().max(1);
        if self.policies.is_empty() {
            self.workers.len()
                * self.thresholds.len()
                * self.deadlines.len()
                * scenarios
                * self.seeds.len()
        } else {
            self.workers.len()
                * self.policies.len()
                * scenarios
                * self.seeds.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Coordinates of point `index` in the fixed serial enumeration
    /// order: workers slowest, then thresholds, then deadlines (or the
    /// policy axis in their place), then scenarios, seeds fastest — the
    /// order a nested `for` loop would visit.
    pub fn params(&self, index: usize) -> SweepParams {
        debug_assert!(index < self.len());
        let seed = self.seeds[index % self.seeds.len()];
        let mut index = index / self.seeds.len();
        let scenario = if self.scenarios.is_empty() {
            None
        } else {
            let plan = self.scenarios[index % self.scenarios.len()].clone();
            index /= self.scenarios.len();
            Some(plan)
        };
        if self.policies.is_empty() {
            let deadline = self.deadlines[index % self.deadlines.len()];
            let index = index / self.deadlines.len();
            let threshold = self.thresholds[index % self.thresholds.len()];
            let index = index / self.thresholds.len();
            let workers = self.workers[index % self.workers.len()];
            SweepParams {
                workers,
                threshold,
                deadline,
                seed,
                policy: None,
                scenario,
            }
        } else {
            let policy = self.policies[index % self.policies.len()].clone();
            let index = index / self.policies.len();
            let workers = self.workers[index % self.workers.len()];
            let eff = policy.effective();
            SweepParams {
                workers,
                threshold: eff.tau.unwrap_or(0.0),
                deadline: eff.step_deadline.unwrap_or(0.0),
                seed,
                policy: Some(policy),
                scenario,
            }
        }
    }

    /// The simulator seed for a point: a pure function of the point's
    /// seed coordinate (never of execution order), run through
    /// SplitMix64 so adjacent user seeds (0, 1, 2, ...) land on
    /// well-separated streams. Points sharing a seed coordinate across
    /// the other axes intentionally share a stream — paired
    /// common-random-number comparisons between arms.
    pub fn sim_seed(params: &SweepParams) -> u64 {
        SplitMix64::new(params.seed ^ SEED_DOMAIN).next_u64()
    }

    /// The whole drop surface of point `p` as one [`DropPolicy`]: the
    /// point's own policy on the policy axis (with the spec-level
    /// Local-SGD period folded in if the policy doesn't carry one), or
    /// the legacy `(threshold, deadline, period)` coordinates composed
    /// into the equivalent policy.
    fn point_policy(&self, p: &SweepParams) -> DropPolicy {
        let mut policy = match &p.policy {
            Some(policy) => policy.clone(),
            None => {
                let mut policy = DropPolicy::None;
                if p.threshold > 0.0 {
                    policy = policy.and(DropPolicy::compute_tau(p.threshold));
                }
                if p.deadline > 0.0 {
                    policy = policy.and(DropPolicy::comm_deadline(p.deadline));
                }
                policy
            }
        };
        if self.period > 1 && policy.local_sgd_h().is_none() {
            policy = policy.and(DropPolicy::local_sgd(self.period));
        }
        policy
    }

    /// Measure one grid point. Pure per index — this is what makes the
    /// parallel run bitwise identical to the serial one.
    pub fn run_point(&self, index: usize) -> SweepPoint {
        self.run_point_pooled(index, &SurvivorCachePool::new())
    }

    /// [`Self::run_point`] borrowing warm survivor schedules from
    /// `pool` (pure memoization — bitwise the same with or without a
    /// pool, property-tested in `tests/policy_equivalence.rs`).
    pub fn run_point_pooled(
        &self,
        index: usize,
        pool: &SurvivorCachePool,
    ) -> SweepPoint {
        self.run_point_observed(index, pool, &mut NoopObserver)
    }

    /// [`Self::run_point_pooled`] with a [`SimObserver`] receiving
    /// every step's events. The [`NoopObserver`] monomorphization is
    /// the plain point runner, so un-observed sweeps pay nothing.
    pub fn run_point_observed<O: SimObserver>(
        &self,
        index: usize,
        pool: &SurvivorCachePool,
        obs: &mut O,
    ) -> SweepPoint {
        let p = self.params(index);
        let policy = self.point_policy(&p);
        if let Some(trace) = &self.replay {
            return self.run_replay_point(index, &p, policy, trace, pool, obs);
        }
        let mut cfg = self.base.clone();
        cfg.workers = p.workers;
        // the point's policy is its entire drop surface; neutralize the
        // base config's own deadline so nothing is applied twice
        cfg.comm_drop_deadline = 0.0;
        let mut sim = ClusterSim::new(&cfg, Self::sim_seed(&p))
            .with_policy(policy.clone());
        if let Some(plan) = &p.scenario {
            sim = sim.with_fault_plan(plan.clone());
        }
        let mut sim = pool.lend(sim);
        let mut out = StepOutcome::default();
        let mut t_sum = 0.0;
        let mut compute_sum = 0.0;
        let mut completed = 0usize;
        for _ in 0..self.iters {
            sim.step_installed_observed(&mut out, obs);
            t_sum += out.iter_time;
            compute_sum += out.compute_time;
            completed += out.total_completed();
        }
        pool.reclaim(&mut sim);
        // Local-SGD schedules one micro-batch per local step
        let per_iter = policy.local_sgd_h().unwrap_or(cfg.accumulations);
        let scheduled = self.iters * p.workers * per_iter;
        SweepPoint {
            index,
            workers: p.workers,
            threshold: p.threshold,
            deadline: p.deadline,
            seed: p.seed,
            policy: p.policy.as_ref().map(DropPolicy::spec),
            scenario: p.scenario.as_ref().map(FaultPlan::spec),
            mean_iter_time: t_sum / self.iters as f64,
            mean_compute_time: compute_sum / self.iters as f64,
            throughput: completed as f64 / t_sum,
            drop_rate: if scheduled == 0 {
                0.0
            } else {
                1.0 - completed as f64 / scheduled as f64
            },
        }
    }

    /// One replay-axis grid point: the recorded trace re-timed under
    /// the point's policy (the [`crate::analysis::budget_fit`]
    /// evaluator as a grid dimension). Deterministic per index — replay
    /// never samples — so the parallel-equals-serial contract holds
    /// trivially, and the warm survivor caches still amortize the drop
    /// path across points.
    fn run_replay_point<O: SimObserver>(
        &self,
        index: usize,
        p: &SweepParams,
        policy: DropPolicy,
        trace: &TraceRecord,
        pool: &SurvivorCachePool,
        obs: &mut O,
    ) -> SweepPoint {
        assert_eq!(
            p.workers, trace.meta.workers,
            "replay sweeps pin the workers axis to the trace's shape \
             (SweepSpec::replay)"
        );
        let mut sim = ClusterSim::from_trace(trace)
            .expect("SweepSpec::replay holds a validated trace");
        sim.set_policy(&policy);
        if let Some(plan) = &p.scenario {
            // the point's plan replaces any trace-carried one: recorded
            // compute re-timed under this churn schedule
            sim = sim.with_fault_plan(plan.clone());
        }
        let mut sim = pool.lend(sim);
        let iters = self.iters.min(trace.len());
        let mut out = StepOutcome::default();
        let mut t_sum = 0.0;
        let mut compute_sum = 0.0;
        let mut completed = 0usize;
        for _ in 0..iters {
            sim.replay_observed(&mut out, obs).expect(
                "replay point within the recorded length and mode \
                 (policy mode must match the trace)",
            );
            t_sum += out.iter_time;
            compute_sum += out.compute_time;
            completed += out.total_completed();
        }
        pool.reclaim(&mut sim);
        let per_iter =
            policy.local_sgd_h().unwrap_or(trace.meta.accums);
        let scheduled = iters * p.workers * per_iter;
        SweepPoint {
            index,
            workers: p.workers,
            threshold: p.threshold,
            deadline: p.deadline,
            seed: p.seed,
            policy: p.policy.as_ref().map(DropPolicy::spec),
            scenario: p.scenario.as_ref().map(FaultPlan::spec),
            mean_iter_time: t_sum / iters.max(1) as f64,
            mean_compute_time: compute_sum / iters.max(1) as f64,
            throughput: if t_sum > 0.0 {
                completed as f64 / t_sum
            } else {
                0.0
            },
            drop_rate: if scheduled == 0 {
                0.0
            } else {
                1.0 - completed as f64 / scheduled as f64
            },
        }
    }

    /// Run the whole grid, fanning points over the thread pool. Output
    /// is in serial enumeration order and bitwise identical to a
    /// `jobs = 1` run (property-tested in `tests/perf_equivalence.rs`).
    /// One [`SurvivorCachePool`] spans the run, so points sharing a
    /// comm model reuse each other's compiled survivor schedules.
    pub fn run(&self) -> SweepResult {
        let spec = Arc::new(self.clone());
        let pool = Arc::new(SurvivorCachePool::new());
        let label = if self.progress { Some("sweep") } else { None };
        if self.batched() {
            // seed-axis batching: each parallel task is one chunk of
            // seed-adjacent points advanced in lockstep through a
            // ReplicaBatch SoA pass. Chunks are pure per index and
            // flattened in chunk order, so the point list is bitwise
            // independent of both `jobs` and `batch` (batched == scalar
            // per replica; property-tested in
            // `tests/batch_equivalence.rs`).
            let chunks = self.batch_chunks();
            let groups = run_indexed(chunks, self.jobs, label, move |c| {
                let (start, count) = spec.chunk_range(c);
                spec.run_batch_points(start, count, &pool)
            });
            let mut points = Vec::with_capacity(self.len());
            for group in groups {
                points.extend(group);
            }
            return SweepResult { points };
        }
        let points =
            run_indexed(self.len(), self.jobs, label, move |i| {
                spec.run_point_pooled(i, &pool)
            });
        SweepResult { points }
    }

    /// Whether this spec takes the seed-axis batched path: a batch
    /// width above 1 and more than one seed to fuse. Replay points
    /// re-time a recorded trace — the seed axis is inert there — so
    /// they always run scalar.
    fn batched(&self) -> bool {
        self.batch.max(1) > 1 && self.seeds.len() > 1 && self.replay.is_none()
    }

    /// Number of lockstep chunks the grid decomposes into at the
    /// current batch width — the parallel task count of a batched run.
    /// Seeds are the fastest-varying axis, so every chunk is a run of
    /// consecutive indices sharing all non-seed coordinates.
    fn batch_chunks(&self) -> usize {
        let s = self.seeds.len().max(1);
        let b = self.batch.max(1).min(s);
        let per_group = s.div_ceil(b);
        (self.len() / s) * per_group
    }

    /// `(start_index, point_count)` of batched chunk `chunk`.
    fn chunk_range(&self, chunk: usize) -> (usize, usize) {
        let s = self.seeds.len().max(1);
        let b = self.batch.max(1).min(s);
        let per_group = s.div_ceil(b);
        let group = chunk / per_group;
        let slot = chunk % per_group;
        (group * s + slot * b, b.min(s - slot * b))
    }

    /// Measure `count` seed-adjacent points in lockstep. Per-point
    /// construction, accumulation and [`SweepPoint`] assembly replicate
    /// [`Self::run_point_observed`] exactly; only the stepping is
    /// fused, and batched stepping is bitwise equal to scalar stepping
    /// per replica — so the returned points carry the bits the scalar
    /// path would have produced.
    fn run_batch_points(
        &self,
        start: usize,
        count: usize,
        pool: &SurvivorCachePool,
    ) -> Vec<SweepPoint> {
        if count <= 1 {
            return (start..start + count)
                .map(|i| self.run_point_pooled(i, pool))
                .collect();
        }
        let p0 = self.params(start);
        let policy = self.point_policy(&p0);
        let mut cfg = self.base.clone();
        cfg.workers = p0.workers;
        // the point's policy is its entire drop surface; neutralize the
        // base config's own deadline so nothing is applied twice
        cfg.comm_drop_deadline = 0.0;
        let mut params = Vec::with_capacity(count);
        let mut sims = Vec::with_capacity(count);
        for i in start..start + count {
            let p = self.params(i);
            let mut sim = ClusterSim::new(&cfg, Self::sim_seed(&p))
                .with_policy(policy.clone());
            if let Some(plan) = &p.scenario {
                sim = sim.with_fault_plan(plan.clone());
            }
            sims.push(sim);
            params.push(p);
        }
        let mut batch = ReplicaBatch::from_sims(sims);
        if let Some(cache) = pool.lend_cache(batch.sims()[0].comm_model()) {
            batch = batch.with_survivor_cache(cache);
        }
        let mut outs = vec![StepOutcome::default(); count];
        let mut t_sum = vec![0.0f64; count];
        let mut compute_sum = vec![0.0f64; count];
        let mut completed = vec![0usize; count];
        for _ in 0..self.iters {
            batch.step_installed_into(&mut outs);
            for (r, out) in outs.iter().enumerate() {
                t_sum[r] += out.iter_time;
                compute_sum[r] += out.compute_time;
                completed[r] += out.total_completed();
            }
        }
        let cache = batch.take_survivor_cache();
        pool.reclaim_cache(batch.sims()[0].comm_model(), cache);
        // Local-SGD schedules one micro-batch per local step
        let per_iter = policy.local_sgd_h().unwrap_or(cfg.accumulations);
        let mut points = Vec::with_capacity(count);
        for (r, p) in params.iter().enumerate() {
            let scheduled = self.iters * p.workers * per_iter;
            points.push(SweepPoint {
                index: start + r,
                workers: p.workers,
                threshold: p.threshold,
                deadline: p.deadline,
                seed: p.seed,
                policy: p.policy.as_ref().map(DropPolicy::spec),
                scenario: p.scenario.as_ref().map(FaultPlan::spec),
                mean_iter_time: t_sum[r] / self.iters as f64,
                mean_compute_time: compute_sum[r] / self.iters as f64,
                throughput: completed[r] as f64 / t_sum[r],
                drop_rate: if scheduled == 0 {
                    0.0
                } else {
                    1.0 - completed[r] as f64 / scheduled as f64
                },
            });
        }
        points
    }

    /// [`Self::run`] with observability: each point records into its
    /// own [`ObsRecorder`] (pure per index), and the per-point
    /// recorders fold into one merged recorder **in index order** after
    /// [`run_indexed`] returns them — so both the per-point shards and
    /// the merged histogram are bitwise independent of `--jobs`
    /// (property-tested in `tests/obs_equivalence.rs`).
    pub fn run_observed(&self) -> (SweepResult, SweepObs) {
        let spec = Arc::new(self.clone());
        let pool = Arc::new(SurvivorCachePool::new());
        let label = if self.progress { Some("sweep") } else { None };
        let pairs = if self.batched() {
            // observed points keep the scalar pass — recorders consume
            // per-phase readiness slices the SoA pass does not build —
            // but run chunk-grouped so scheduling matches the batched
            // unobserved run. Each point is still pure per index, so
            // per-point shards and the merged fold below are bitwise
            // independent of `jobs` *and* `batch`.
            let chunks = self.batch_chunks();
            let groups = run_indexed(chunks, self.jobs, label, move |c| {
                let (start, count) = spec.chunk_range(c);
                (start..start + count)
                    .map(|i| {
                        let mut rec = ObsRecorder::new(0);
                        let point =
                            spec.run_point_observed(i, &pool, &mut rec);
                        (point, rec)
                    })
                    .collect::<Vec<_>>()
            });
            groups.into_iter().flatten().collect::<Vec<_>>()
        } else {
            run_indexed(self.len(), self.jobs, label, move |i| {
                let mut rec = ObsRecorder::new(0);
                let point = spec.run_point_observed(i, &pool, &mut rec);
                (point, rec)
            })
        };
        let mut points = Vec::with_capacity(pairs.len());
        let mut per_point = Vec::with_capacity(pairs.len());
        let mut merged = ObsRecorder::new(0);
        for (p, rec) in pairs {
            merged.merge(&rec);
            points.push(p);
            per_point.push(rec);
        }
        (SweepResult { points }, SweepObs { per_point, merged })
    }
}

/// Observability output of [`SweepSpec::run_observed`]: one recorder
/// per grid point (index order) plus their deterministic merge.
#[derive(Debug, Clone, Default)]
pub struct SweepObs {
    pub per_point: Vec<ObsRecorder>,
    pub merged: ObsRecorder,
}

impl SweepResult {
    /// Render as a JSON document (round-trips through the crate's own
    /// parser; asserted by the unit tests).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"sweep\",\n  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            let policy = match &p.policy {
                // policy spec strings contain no JSON-special characters
                Some(spec) => format!("\"policy\": \"{spec}\", "),
                None => String::new(),
            };
            let scenario = match &p.scenario {
                // scenario spec strings are JSON-clean too
                Some(spec) => format!("\"scenario\": \"{spec}\", "),
                None => String::new(),
            };
            s.push_str(&format!(
                "    {{\"index\": {}, \"workers\": {}, \"threshold\": {:?}, \
                 \"deadline\": {:?}, \"seed\": {}, {}{}\"mean_iter_time\": {:?}, \
                 \"mean_compute_time\": {:?}, \"throughput\": {:?}, \
                 \"drop_rate\": {:?}}}{}\n",
                p.index,
                p.workers,
                p.threshold,
                p.deadline,
                p.seed,
                policy,
                scenario,
                p.mean_iter_time,
                p.mean_compute_time,
                p.throughput,
                p.drop_rate,
                if i + 1 < self.points.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NoiseKind;
    use crate::runtime::json::Json;

    fn base() -> ClusterConfig {
        ClusterConfig {
            workers: 4,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.2,
            noise: NoiseKind::Exponential { mean: 0.1 },
            ..Default::default()
        }
    }

    #[test]
    fn enumeration_order_is_the_nested_loop_order() {
        let spec = SweepSpec::new(base())
            .workers(&[2, 4])
            .thresholds(&[0.0, 3.0])
            .deadlines(&[0.0])
            .seeds(&[7, 8, 9]);
        assert_eq!(spec.len(), 12);
        let mut idx = 0;
        for &w in &[2usize, 4] {
            for &tau in &[0.0, 3.0] {
                for &seed in &[7u64, 8, 9] {
                    let p = spec.params(idx);
                    assert_eq!(
                        p,
                        SweepParams {
                            workers: w,
                            threshold: tau,
                            deadline: 0.0,
                            seed,
                            policy: None,
                            scenario: None,
                        },
                        "idx={idx}"
                    );
                    idx += 1;
                }
            }
        }
    }

    #[test]
    fn sim_seed_is_pure_and_decorrelates_adjacent_seeds() {
        let p = |workers, threshold, deadline, seed| SweepParams {
            workers,
            threshold,
            deadline,
            seed,
            policy: None,
            scenario: None,
        };
        let a = p(2, 0.0, 0.0, 0);
        let b = p(2, 0.0, 0.0, 1);
        assert_eq!(SweepSpec::sim_seed(&a), SweepSpec::sim_seed(&a));
        assert_ne!(SweepSpec::sim_seed(&a), SweepSpec::sim_seed(&b));
        // the sim seed ignores the non-seed axes: paired comparisons
        let c = p(64, 9.0, 2.0, 0);
        assert_eq!(SweepSpec::sim_seed(&a), SweepSpec::sim_seed(&c));
    }

    #[test]
    fn run_covers_the_grid_and_json_parses() {
        let spec = SweepSpec::new(base())
            .workers(&[2, 3])
            .thresholds(&[0.0, 2.0])
            .seeds(&[1, 2])
            .iters(5)
            .jobs(2);
        let result = spec.run();
        assert_eq!(result.points.len(), 8);
        for (i, p) in result.points.iter().enumerate() {
            assert_eq!(p.index, i);
            assert!(p.mean_iter_time > 0.0);
            assert!(p.throughput > 0.0);
            assert!((0.0..=1.0).contains(&p.drop_rate));
        }
        let doc = Json::parse(&result.to_json()).expect("valid JSON");
        assert_eq!(
            doc.get("points").unwrap().as_arr().unwrap().len(),
            8
        );
    }

    #[test]
    fn period_axis_measures_local_sgd() {
        let mut cfg = base();
        cfg.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 1.0 };
        let spec = SweepSpec::new(cfg.clone())
            .workers(&[4])
            .thresholds(&[0.0, 0.8])
            .seeds(&[5])
            .iters(10)
            .period(6)
            .jobs(1);
        let r = spec.run();
        assert_eq!(r.points.len(), 2);
        // bitwise equal to a manual Local-SGD loop with the same
        // derived seed
        let p = spec.params(0);
        let mut cfg0 = cfg.clone();
        cfg0.workers = 4;
        cfg0.comm_drop_deadline = p.deadline;
        let mut sim = ClusterSim::new(&cfg0, SweepSpec::sim_seed(&p));
        let want = sim.mean_period_time(10, 6, None);
        assert_eq!(r.points[0].mean_iter_time.to_bits(), want.to_bits());
        // the thresholded arm drops local steps; drop_rate is counted
        // against workers x H per period
        assert_eq!(r.points[0].drop_rate, 0.0);
        assert!(r.points[1].drop_rate > 0.0);
        assert!(r.points[1].drop_rate < 1.0);
    }

    #[test]
    fn policy_axis_subsumes_legacy_axes_bitwise() {
        // every legacy (tau, deadline) cell expressed as one DropPolicy
        // must reproduce the legacy grid bit for bit, point for point
        let mut cfg = base();
        cfg.topology = Some(crate::topology::TopologyKind::Ring);
        cfg.link_latency = 1e-4;
        cfg.link_bandwidth = 1e9;
        cfg.grad_bytes = 4e6;
        let legacy = SweepSpec::new(cfg.clone())
            .workers(&[3, 6])
            .thresholds(&[0.0, 2.0])
            .deadlines(&[0.0, 1.0])
            .seeds(&[4, 5])
            .iters(6)
            .jobs(1)
            .run();
        let mut policies = Vec::new();
        for &tau in &[0.0, 2.0] {
            for &d in &[0.0, 1.0] {
                let mut p = DropPolicy::None;
                if tau > 0.0 {
                    p = p.and(DropPolicy::compute_tau(tau));
                }
                if d > 0.0 {
                    p = p.and(DropPolicy::comm_deadline(d));
                }
                policies.push(p);
            }
        }
        let unified = SweepSpec::new(cfg)
            .workers(&[3, 6])
            .policies(&policies)
            .seeds(&[4, 5])
            .iters(6)
            .jobs(1)
            .run();
        assert_eq!(legacy.points.len(), unified.points.len());
        for (a, b) in legacy.points.iter().zip(&unified.points) {
            assert_eq!(a.workers, b.workers);
            assert_eq!(a.seed, b.seed);
            assert_eq!(
                a.mean_iter_time.to_bits(),
                b.mean_iter_time.to_bits(),
                "point {} ({:?})",
                a.index,
                b.policy
            );
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits());
            assert!(b.policy.is_some());
            assert!(a.policy.is_none());
        }
    }

    #[test]
    fn policy_axis_sweeps_per_phase_and_local_sgd() {
        let mut cfg = base();
        cfg.topology = Some(crate::topology::TopologyKind::Torus { rows: 0 });
        cfg.link_latency = 1e-4;
        cfg.link_bandwidth = 1e9;
        cfg.grad_bytes = 4e6;
        cfg.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.4, delay: 4.0 };
        let policies = [
            DropPolicy::None,
            DropPolicy::parse("phase-deadline=1/0.2/0.2").unwrap(),
            DropPolicy::parse("local-sgd=5+tau=0.9").unwrap(),
        ];
        let r = SweepSpec::new(cfg)
            .workers(&[6])
            .policies(&policies)
            .seeds(&[2])
            .iters(10)
            .jobs(1)
            .run();
        assert_eq!(r.points.len(), 3);
        assert_eq!(r.points[0].policy.as_deref(), Some("none"));
        assert_eq!(
            r.points[1].policy.as_deref(),
            Some("phase-deadline=1/0.2/0.2")
        );
        assert_eq!(r.points[0].drop_rate, 0.0);
        assert!(
            r.points[1].drop_rate > 0.0,
            "per-phase budgets must drop under heavy stragglers"
        );
        assert!(
            r.points[1].mean_iter_time < r.points[0].mean_iter_time,
            "dropping the tail must shorten the step"
        );
        // the Local-SGD arm counts scheduled work per local step
        assert!(r.points[2].drop_rate > 0.0);
        assert!(r.points[2].drop_rate < 1.0);
        // JSON carries the policy axis and round-trips
        let doc = Json::parse(&r.to_json()).expect("valid JSON");
        let pts = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(
            pts[1].get("policy").and_then(Json::as_str),
            Some("phase-deadline=1/0.2/0.2")
        );
    }

    #[test]
    fn replay_axis_sweeps_a_recorded_trace_deterministically() {
        // record once, sweep policies over the recording: points are a
        // pure function of the policy (the seed axis is inert), the
        // parallel run is bitwise the serial one, and each point equals
        // the direct replay evaluator
        let mut cfg = base();
        cfg.workers = 5;
        cfg.noise = NoiseKind::Exponential { mean: 0.4 };
        cfg.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 3.0 };
        cfg.topology = Some(crate::topology::TopologyKind::Ring);
        cfg.link_latency = 1e-4;
        cfg.link_bandwidth = 1e9;
        cfg.grad_bytes = 4e6;
        let mut sim = ClusterSim::new(&cfg, 0x5EED);
        sim.start_recording();
        for _ in 0..12 {
            sim.step(None);
        }
        let trace = sim.finish_recording().unwrap();
        let policies = [
            DropPolicy::None,
            DropPolicy::comm_deadline(1.0),
            DropPolicy::parse("tau=2.5+deadline=1").unwrap(),
        ];
        let spec = SweepSpec::new(cfg)
            .policies(&policies)
            .seeds(&[1, 2])
            .iters(12)
            .replay(trace.clone());
        assert_eq!(spec.len(), 6, "workers axis pinned to the trace");
        let serial = spec.clone().jobs(1).run();
        let parallel = spec.clone().jobs(3).run();
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.mean_iter_time.to_bits(), b.mean_iter_time.to_bits());
            assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits());
        }
        // seeds are inert under replay: seed-1 and seed-2 rows agree
        for i in 0..3 {
            let (s1, s2) = (&serial.points[2 * i], &serial.points[2 * i + 1]);
            assert_eq!(
                s1.mean_iter_time.to_bits(),
                s2.mean_iter_time.to_bits(),
                "policy {i}"
            );
        }
        // each point equals the direct replay evaluator
        let (want, _) = crate::analysis::evaluate_policy(
            &trace,
            &policies[1],
        )
        .unwrap();
        assert_eq!(serial.points[2].mean_iter_time.to_bits(), want.to_bits());
        // and the baseline row is the recorded run itself
        let recorded_mean = trace
            .outcomes
            .iter()
            .map(|o| o.iter_time)
            .sum::<f64>()
            / trace.len() as f64;
        assert_eq!(
            serial.points[0].mean_iter_time.to_bits(),
            recorded_mean.to_bits()
        );
    }

    #[test]
    fn scenario_axis_multiplies_the_grid_and_rides_into_json() {
        let plans = [
            FaultPlan::default(),
            FaultPlan::parse("fail@2:w0,rejoin+3").unwrap(),
        ];
        let spec = SweepSpec::new(base())
            .workers(&[3])
            .thresholds(&[0.0])
            .scenarios(&plans)
            .seeds(&[1, 2])
            .iters(6)
            .jobs(1);
        assert_eq!(spec.len(), 4, "scenario axis multiplies the grid");
        // enumeration: seeds fastest, scenarios next
        assert_eq!(spec.params(0).scenario, Some(plans[0].clone()));
        assert_eq!(spec.params(1).seed, 2);
        assert_eq!(spec.params(2).scenario, Some(plans[1].clone()));
        let r = spec.clone().run();
        // the fault-free arm drops nothing; the churn arm loses worker
        // 0's seat (and its scheduled work) while it is down
        assert_eq!(r.points[0].drop_rate, 0.0);
        assert!(r.points[2].drop_rate > 0.0);
        assert!(r.points[2].drop_rate < 1.0);
        assert_eq!(r.points[0].scenario.as_deref(), Some("none"));
        assert_eq!(
            r.points[2].scenario.as_deref(),
            Some("fail@2:w0,rejoin+3")
        );
        // parallel run is bitwise the serial one
        let par = spec.jobs(3).run();
        for (a, b) in r.points.iter().zip(&par.points) {
            assert_eq!(a.mean_iter_time.to_bits(), b.mean_iter_time.to_bits());
            assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits());
        }
        // JSON carries the scenario axis
        let doc = Json::parse(&r.to_json()).expect("valid JSON");
        let pts = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(
            pts[2].get("scenario").and_then(Json::as_str),
            Some("fail@2:w0,rejoin+3")
        );
        assert!(pts[0].get("scenario").is_some());
    }

    #[test]
    fn threshold_axis_actually_drops_work() {
        let mut cfg = base();
        cfg.noise = NoiseKind::Exponential { mean: 0.5 };
        let spec = SweepSpec::new(cfg)
            .workers(&[8])
            .thresholds(&[0.0, 1.2])
            .seeds(&[3])
            .iters(20)
            .jobs(1);
        let r = spec.run();
        assert_eq!(r.points[0].drop_rate, 0.0, "baseline drops nothing");
        assert!(r.points[1].drop_rate > 0.0, "tight tau must drop");
        assert!(r.points[1].mean_compute_time <= 1.2 + 1e-9);
    }
}
