//! Warm survivor-schedule caches shared across sweep points.
//!
//! Every grid point used to construct its own [`ClusterSim`] — and with
//! it a cold [`SurvivorScheduleCache`], so each point re-paid the
//! per-survivor-count schedule compiles the PR-3 cache exists to
//! amortize. But survivor schedules depend only on the *comm model*
//! (topology kind + link parameters): a k-member schedule is the same
//! whatever the full cluster size, so one warm cache can serve every
//! point of a grid that shares a topology — across worker counts,
//! thresholds, deadlines, policies and seeds.
//!
//! [`SurvivorCachePool`] is that hand-off point. Threads check a cache
//! out before a point and return it after; if another thread holds the
//! pool entry, the point simply runs with a cold cache (correct, just
//! unwarmed — memoization can never change a result, only skip
//! compiles, which is what keeps the parallel sweep bitwise identical
//! to the serial one; property-tested in `tests/policy_equivalence.rs`).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::sim::{ClusterSim, CommModel, SurvivorScheduleCache};
use crate::topology::TopologyKind;

/// The comm-model identity a survivor cache is valid for: topology kind
/// plus the exact link-parameter bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PoolKey {
    kind: TopologyKind,
    latency: u64,
    bandwidth: u64,
    bytes: u64,
}

fn pool_key(model: &CommModel) -> Option<PoolKey> {
    match *model {
        // the fixed-T^c model compiles nothing; pooling buys nothing
        CommModel::Fixed(_) => None,
        CommModel::Ring { latency, bandwidth, bytes } => Some(PoolKey {
            kind: TopologyKind::Ring,
            latency: latency.to_bits(),
            bandwidth: bandwidth.to_bits(),
            bytes: bytes.to_bits(),
        }),
        CommModel::Topology { kind, latency, bandwidth, bytes } => {
            Some(PoolKey {
                kind,
                latency: latency.to_bits(),
                bandwidth: bandwidth.to_bits(),
                bytes: bytes.to_bits(),
            })
        }
    }
}

/// Shared pool of warm [`SurvivorScheduleCache`]s, keyed by comm model.
/// One per [`super::SweepSpec::run`]; threads check caches in and out
/// around each grid point.
#[derive(Debug, Default)]
pub struct SurvivorCachePool {
    slots: Mutex<BTreeMap<PoolKey, Vec<SurvivorScheduleCache>>>,
}

impl SurvivorCachePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Hand `sim` a warm cache for its comm model, if the pool has one.
    pub fn lend(&self, sim: ClusterSim) -> ClusterSim {
        let Some(key) = pool_key(sim.comm_model()) else { return sim };
        let cache = {
            let mut slots = self.slots.lock().expect("cache pool poisoned");
            slots.get_mut(&key).and_then(Vec::pop)
        };
        match cache {
            Some(c) => sim.with_survivor_cache(c),
            None => sim,
        }
    }

    /// Take `sim`'s (now warmer) cache back into the pool.
    pub fn reclaim(&self, sim: &mut ClusterSim) {
        let Some(key) = pool_key(sim.comm_model()) else { return };
        let cache = sim.take_survivor_cache();
        let mut slots = self.slots.lock().expect("cache pool poisoned");
        slots.entry(key).or_default().push(cache);
    }

    /// Check a warm cache out directly for `model` — the batch-level
    /// seam: a [`crate::sim::ReplicaBatch`] holds one shared cache for
    /// all its replicas' fallback drop branches, rather than one per
    /// sim. `None` when the pool has nothing warm (or the model
    /// compiles nothing); the batch then runs with its own cold cache.
    pub fn lend_cache(
        &self,
        model: &CommModel,
    ) -> Option<SurvivorScheduleCache> {
        let key = pool_key(model)?;
        let mut slots = self.slots.lock().expect("cache pool poisoned");
        slots.get_mut(&key).and_then(Vec::pop)
    }

    /// Return a batch's (now warmer) shared cache for `model` to the
    /// pool. Caches for the fixed-`T^c` model compile nothing and are
    /// dropped, mirroring [`Self::reclaim`].
    pub fn reclaim_cache(
        &self,
        model: &CommModel,
        cache: SurvivorScheduleCache,
    ) {
        let Some(key) = pool_key(model) else { return };
        if !cache.matches(model) {
            return;
        }
        let mut slots = self.slots.lock().expect("cache pool poisoned");
        slots.entry(key).or_default().push(cache);
    }

    /// Total compiled survivor schedules currently pooled (test /
    /// diagnostics introspection).
    pub fn compiled_count(&self) -> usize {
        let slots = self.slots.lock().expect("cache pool poisoned");
        slots
            .values()
            .flat_map(|v| v.iter())
            .map(SurvivorScheduleCache::compiled_count)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, NoiseKind, StragglerKind};

    fn drop_heavy() -> ClusterConfig {
        ClusterConfig {
            workers: 8,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.5 },
            stragglers: StragglerKind::Uniform { p: 0.4, delay: 5.0 },
            topology: Some(TopologyKind::Torus { rows: 0 }),
            comm_drop_deadline: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn pool_round_trip_amortizes_compiles() {
        let pool = SurvivorCachePool::new();
        let cfg = drop_heavy();
        // first point: cold cache, compiles happen
        let mut sim = pool.lend(ClusterSim::new(&cfg, 1));
        for _ in 0..15 {
            sim.step(None);
        }
        pool.reclaim(&mut sim);
        let warmed = pool.compiled_count();
        assert!(warmed > 0, "drop-heavy config must compile something");
        // second point, different N, same comm model: reuses the warm
        // cache, and identical outcomes to a cold run
        let mut cfg2 = cfg.clone();
        cfg2.workers = 5;
        let mut pooled = pool.lend(ClusterSim::new(&cfg2, 2));
        let mut cold = ClusterSim::new(&cfg2, 2);
        for _ in 0..15 {
            let a = pooled.step(None);
            let b = cold.step(None);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
        pool.reclaim(&mut pooled);
        assert!(
            pool.compiled_count() >= warmed,
            "reclaimed cache keeps its compiles"
        );
    }

    #[test]
    fn fixed_model_is_not_pooled() {
        let pool = SurvivorCachePool::new();
        let mut cfg = drop_heavy();
        cfg.topology = None;
        let mut sim = pool.lend(ClusterSim::new(&cfg, 1));
        sim.step(None);
        pool.reclaim(&mut sim);
        assert_eq!(pool.compiled_count(), 0);
    }
}
