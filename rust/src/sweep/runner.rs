//! Deterministic parallel index runner with progress/ETA reporting.
//!
//! [`run_indexed`] is the execution core of the sweep engine: it runs a
//! pure-per-index function over `0..n` on a [`ThreadPool`] and returns
//! the results **in index order**, so the output is bitwise identical
//! to a serial loop no matter how the scheduler interleaves the jobs.
//! Determinism therefore rests on one contract: `f(i)` must depend only
//! on `i` (every sweep point seeds its own simulator — see
//! [`super::grid::SweepSpec`]).

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::ThreadPool;

/// Resolve a `--jobs` value: 0 means "all cores".
pub fn resolve_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        ThreadPool::default_size()
    } else {
        jobs
    }
}

/// Run `f(i)` for `i in 0..n` across `jobs` threads (0 = auto) and
/// collect the results in index order. `progress: Some(label)` reports
/// throughput and ETA to stderr as points complete.
pub fn run_indexed<T, F>(
    n: usize,
    jobs: usize,
    progress: Option<&str>,
    f: F,
) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let jobs = resolve_jobs(jobs).min(n.max(1));
    let mut prog = progress.map(|label| Progress::new(label, n));
    if jobs <= 1 {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(f(i));
            if let Some(p) = prog.as_mut() {
                p.tick();
            }
        }
        return out;
    }
    let pool = ThreadPool::new(jobs);
    pool.map_indexed_with(n, f, |_done| {
        if let Some(p) = prog.as_mut() {
            p.tick();
        }
    })
}

/// Throttled progress/ETA reporter (at most ~2 lines per second, plus
/// a final line at completion). Lines go through [`crate::obs::log`]
/// at info level — always stderr, suppressed by `--quiet` — so
/// progress never interleaves with machine-readable results on stdout.
pub struct Progress {
    label: String,
    total: usize,
    done: usize,
    started: Instant,
    last_print: Option<Instant>,
    /// Completion times of the last [`ETA_WINDOW`] points — the moving
    /// window the throughput/ETA is computed from.
    recent: VecDeque<Instant>,
}

/// Completions kept in the [`Progress`] moving window.
const ETA_WINDOW: usize = 32;

impl Progress {
    pub fn new(label: &str, total: usize) -> Self {
        Self {
            label: label.to_string(),
            total,
            done: 0,
            started: Instant::now(),
            last_print: None,
            recent: VecDeque::with_capacity(ETA_WINDOW),
        }
    }

    pub fn tick(&mut self) {
        self.done += 1;
        if !crate::obs::log::enabled(crate::obs::log::Level::Info) {
            return; // --quiet: skip even the rate-limit bookkeeping
        }
        let now = Instant::now();
        self.record(now);
        let due = match self.last_print {
            None => true,
            Some(t) => now.duration_since(t).as_secs_f64() >= 0.5,
        };
        if !(due || self.done == self.total) {
            return;
        }
        self.last_print = Some(now);
        let elapsed = now.duration_since(self.started).as_secs_f64();
        let (rate, eta) = self.rate_and_eta(now);
        crate::info!(
            "[{}] {}/{} points ({:.1}%) — {:.1} pts/s, {:.1}s elapsed, ETA {:.1}s",
            self.label,
            self.done,
            self.total,
            100.0 * self.done as f64 / self.total.max(1) as f64,
            rate,
            elapsed,
            eta,
        );
    }

    fn record(&mut self, now: Instant) {
        if self.recent.len() == ETA_WINDOW {
            self.recent.pop_front();
        }
        self.recent.push_back(now);
    }

    /// Throughput and ETA from the completion moving window: the rate
    /// over the last up-to-[`ETA_WINDOW`] points, not the whole run.
    /// A grid whose per-point cost grows as an axis advances (more
    /// workers, bigger schedules) gets an ETA tracking the *current*
    /// cost instead of the stale run-wide mean. Falls back to the
    /// overall rate until two completions have landed.
    fn rate_and_eta(&self, now: Instant) -> (f64, f64) {
        let rate = match self.recent.front() {
            Some(&first) if self.recent.len() >= 2 => {
                let span = now.duration_since(first).as_secs_f64();
                (self.recent.len() - 1) as f64 / span.max(1e-9)
            }
            _ => {
                let elapsed = now.duration_since(self.started).as_secs_f64();
                self.done as f64 / elapsed.max(1e-9)
            }
        };
        let eta = self.total.saturating_sub(self.done) as f64 / rate.max(1e-9);
        (rate, eta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for jobs in [1usize, 2, 4, 0] {
            let out = run_indexed(64, jobs, None, |i| i * 3);
            assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_equals_serial_for_pure_functions() {
        // the contract the sweep engine relies on: f(i) pure per index
        // makes execution order invisible.
        let f = |i: usize| {
            let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(i as u64);
            (0..100).map(|_| rng.next_f64()).sum::<f64>()
        };
        let serial = run_indexed(40, 1, None, f);
        let parallel = run_indexed(40, 4, None, f);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_and_single_item_grids() {
        let out: Vec<usize> = run_indexed(0, 4, None, |i| i);
        assert!(out.is_empty());
        let one = run_indexed(1, 0, None, |i| i + 7);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn resolve_jobs_auto() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    #[test]
    fn progress_counts_to_total() {
        let mut p = Progress::new("test", 3);
        p.tick();
        p.tick();
        p.tick();
        assert_eq!(p.done, 3);
    }

    #[test]
    fn eta_tracks_the_recent_rate_not_the_global_mean() {
        use std::time::Duration;
        let mut p = Progress::new("test", 100);
        let mut t = Instant::now();
        // 40 fast points (10/s) followed by 32 slow ones (1/s): the
        // window only sees slow completions by the end
        for _ in 0..40 {
            t += Duration::from_millis(100);
            p.done += 1;
            p.record(t);
        }
        for _ in 0..32 {
            t += Duration::from_secs(1);
            p.done += 1;
            p.record(t);
        }
        let (rate, eta) = p.rate_and_eta(t);
        assert!(
            (0.6..1.5).contains(&rate),
            "windowed rate ~1 pt/s, got {rate}"
        );
        // 28 points remain at ~1/s; the run-wide mean (2 pts/s) would
        // claim ~14s — the moving window must not
        assert!(eta > 18.0 && eta < 50.0, "eta {eta}");
    }

    #[test]
    fn eta_falls_back_to_the_overall_rate_early_on() {
        use std::time::Duration;
        let started = Instant::now();
        let mut p = Progress::new("test", 10);
        p.started = started;
        p.done = 1;
        p.record(started + Duration::from_secs(2));
        let (rate, eta) =
            p.rate_and_eta(started + Duration::from_secs(2));
        assert!((rate - 0.5).abs() < 1e-9, "1 point / 2s, got {rate}");
        assert!((eta - 18.0).abs() < 1e-6, "9 points / 0.5 pt/s, got {eta}");
    }
}
