//! Parallel scenario-sweep engine.
//!
//! Every figure in the paper is a sweep — iteration time vs. `N`
//! (Fig 1), vs. threshold (Fig 6), vs. noise family/variance
//! (Figs 13/14) — and the roadmap's scenario grids multiply those axes
//! together: `workers × threshold × DropComm deadline × seed`, times
//! topologies and noise kinds in the base config. This subsystem turns
//! that product into a first-class object and runs it as fast as the
//! machine allows:
//!
//! * [`grid`] — [`SweepSpec`] (builder for the 4-axis grid — or the
//!   `workers × policies × seeds` grid over
//!   [`crate::policy::DropPolicy`]s, which subsumes the
//!   threshold/deadline/period axes — fixed serial enumeration order,
//!   per-point derived seeds), [`SweepPoint`] / [`SweepResult`]
//!   (+ JSON rendering);
//! * [`cache`] — [`SurvivorCachePool`], warm survivor-schedule caches
//!   handed between points that share a comm model, so grid warmup
//!   compiles are paid once per topology instead of once per point;
//! * [`runner`] — [`run_indexed`], the deterministic parallel map over
//!   [`crate::util::ThreadPool`] with progress/ETA reporting.
//!
//! **Determinism contract:** a point's measurement depends only on its
//! grid coordinates (each point seeds its own [`crate::sim::ClusterSim`]
//! from a SplitMix64-derived seed), and [`run_indexed`] returns results
//! in index order — so a `--jobs 32` run is bitwise identical to
//! `--jobs 1`, property-tested in `tests/perf_equivalence.rs`. Combined
//! with the compiled schedule fast path
//! ([`crate::sim::CompiledSchedule`]) this is what makes million-point
//! grids practical (cf. the tail-latency parameter studies of
//! OptiReduce, arXiv:2310.06993).
//!
//! Consumers: [`crate::coordinator::ScaleRun::sweep`], the `scale` /
//! `sweep` CLI subcommands (`--jobs`, `[sweep]` config section), and
//! the figure benches.

pub mod cache;
pub mod grid;
pub mod runner;

pub use cache::SurvivorCachePool;
pub use grid::{SweepObs, SweepParams, SweepPoint, SweepResult, SweepSpec};
pub use runner::{resolve_jobs, run_indexed, Progress};
