//! `dropcompute` — launcher CLI.
//!
//! Subcommands:
//!   train        pretrain a model with/without DropCompute
//!   local-sgd    Local-SGD (+ optional DropCompute) training
//!   simulate     virtual-clock cluster timing (no real compute)
//!   tune         run Algorithm 2 on a simulated trace, print the sweep
//!   scale        throughput-vs-N sweep (Fig 1 style)
//!   trace        record / replay / fit replayable timing traces
//!   analyze      closed-form model: E[T], E[M~], S_eff(tau)
//!
//! Shared flags: `--config <file.toml>`, repeated `--set a.b=v`,
//! `--out <dir>` for CSV/JSON dumps, `--quiet`.

use std::path::PathBuf;
use std::process::ExitCode;

use dropcompute::analysis::{self, Setting};
use dropcompute::cli::{Args, Spec};
use dropcompute::config::Config;
use dropcompute::obs::ObsRecorder;
use dropcompute::coordinator::ScaleRun;
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, pct, Table};
use dropcompute::sim::{ClusterSim, FaultPlan, ReplicaBatch};
use dropcompute::train::{LocalSgdTrainer, Trainer};
use dropcompute::util::Result;

const USAGE: &str = "\
dropcompute — DropCompute (NeurIPS 2023) reproduction

USAGE: dropcompute <SUBCOMMAND> [--config file.toml] [--set a.b=v]... [opts]

SUBCOMMANDS:
  train       pretrain with/without DropCompute   [--out dir]
  local-sgd   Local-SGD + DropCompute             [--periods N] [--tau T]
  simulate    timing-only cluster simulation      [--iters N] [--tau T]
              [--batch S]
  tune        Algorithm 2 threshold sweep         [--iters N]
  scale       throughput vs N sweep               [--workers 8,16,...] [--jobs J]
  sweep       parallel scenario grid: workers x tau x deadline x seed,
              or workers x policy x seed with --policy
              [--workers 8,16] [--thresholds 0,2.5] [--deadlines 0,3]
              [--policy SPEC]... [--seeds 1,2,3] [--iters N] [--jobs J]
              [--batch S] [--out dir]
  trace       record / replay / fit replayable timing traces:
                trace record [--iters N] [--policy SPEC] [--trace file]
                    run the simulator, record per-worker draws +
                    outcomes into versioned JSON ([trace] config keys)
                trace replay [--trace file] [--policy SPEC] [--reference]
                    replay a recorded trace; without a policy override,
                    verifies the recorded outcomes bitwise (conformance)
                trace fit    [--trace file] [--grid G]
                    fit tau + DropComm deadlines (step-level and
                    per-phase) maximizing predicted speedup over the
                    trace; emits a ready-to-use --policy spec
  analyze     closed-form E[T], E[M~], S_eff      [--tau T]
  transport   real-socket loopback collective (the sim-to-real bridge):
                transport run   [--iters N] [--kind uds|tcp] [--policy SPEC]
                                [--scenario SPEC] [--trace file] [--obs-out B]
                    one OS thread + socket endpoint per worker executes
                    the configured topology's schedule with DropCompute
                    membership deadlines, bounded retry, and fault
                    injection; records a v2 trace (transport meta) and
                    gates on bitwise replay + sim-vs-real ordering
                    conformance ([transport] config keys)
                transport bench [--iters N] [--kind uds|tcp] [--smoke]
                    per-topology loopback all-reduce wall time vs the
                    in-process mpsc mesh
  obs         observability utilities:
                obs lint <file.prom>   check Prometheus exposition format
  lint        in-tree invariant lint (static analysis over the crate):
                lint [DIR] [--root DIR] [--baseline FILE] [--deny]
                     [--json FILE] [--update-baseline] [file.prom]...
              walks DIR (default rust/src) enforcing the determinism /
              no-hang / allocation-free rules (see README \"Static
              analysis\"); `--deny` exits non-zero on any active
              deny-severity finding (the CI lint-gate); `.prom`
              positionals run the exposition sub-check

Drop policies (simulate/sweep; the one drop-decision surface):
  --policy SPEC
              clause[+clause]... with clauses
                none | tau=T[,preempt|,between] | deadline=D |
                phase-deadline=B0[/B1...]       | local-sgd=H
              e.g. `tau=9+deadline=3`, `phase-deadline=1.5/0.5/0.5`.
              Repeat --policy in `sweep` for a policy axis (subsumes
              --thresholds/--deadlines). Defaults to the `[policy]`
              config section; legacy --tau/--comm-drop-deadline compose
              into the same surface.

Fault scenarios (simulate/sweep; the churn lab):
  --scenario SPEC
              `;`-separated fault events varying live membership and
              per-worker speed between steps:
                fail@S:wN[,rejoin+R]   worker N dies at step S (rejoins
                                       at S+R when given)
                slow@S:wN,xF[,forD]    worker N runs F x slower from S
                                       (for D steps when given)
                drift@S:wN,+R          worker N degrades by rate R per
                                       step from S on
              e.g. `fail@100:w3,rejoin+50;slow@20:w1,x2.5`; `none` is
              the fault-free plan. Deterministic: same seed + same plan
              give bitwise-identical outcomes on both timing paths.
              Repeat --scenario in `sweep` for a churn axis. Defaults
              to the `[scenario]` config section.

simulate/scale/sweep also take the topology-aware collective model:
  --topology fixed|ring|tree|hierarchical[:group]|torus[:rows]
              event-driven schedule model (`fixed` = the paper's T^c)
  --comm-drop-deadline D
              DropComm: bounded-wait AllReduce, membership closes D
              seconds after the first arrival (0 = wait for everyone)
  --single-restart
              legacy per-phase restart semantics: survivors' restarted
              collective is NOT re-checked against the remaining phase
              budgets (default: recursive re-check)

scale/sweep fan grid points over a thread pool: --jobs J (0 = all
cores, 1 = serial; output is bitwise identical either way). Grid axes
default to the `[sweep]` config section.

simulate/sweep step replicas in SoA lockstep: --batch S (default 1,
`[sweep] batch` config). `simulate --batch S` runs S replicas (seeds
seed..seed+S-1) through one shared compiled phase pass and reports
aggregate stats; `sweep --batch S` chunks the seed axis S-wide per
pass. Batched output is bitwise identical to --batch 1 — the scalar
pass stays the oracle (see tests/batch_equivalence.rs).

Observability (simulate/sweep/trace replay): --obs-out BASE attaches
the zero-overhead step recorder and writes BASE.prom (Prometheus text)
+ BASE.json (snapshot: tail histograms, per-worker straggler table,
drop causes). The `[obs]` config section (`enabled`, `out`) does the
same from a file; `-v`/`--verbose` and `-q`/`--quiet` set the log
level.

Config keys: see configs/*.toml and DESIGN.md.";

fn main() -> ExitCode {
    let spec = Spec::new()
        .subcommands(&[
            "train", "local-sgd", "simulate", "tune", "scale", "sweep",
            "trace", "analyze", "obs", "transport", "lint",
        ])
        .value_keys(&[
            "config", "set", "out", "iters", "tau", "periods", "workers",
            "grid", "topology", "comm-drop-deadline", "jobs", "batch",
            "thresholds",
            "deadlines", "seeds", "policy", "scenario", "trace", "obs-out",
            "kind", "root", "baseline", "json",
        ])
        .short('v', "verbose")
        .short('q', "quiet");
    let args = match spec.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    dropcompute::obs::log::set_from_flags(
        args.flag("quiet"),
        args.flag("verbose"),
    );
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg = args.build_config()?;
    match args.subcommand.as_deref().unwrap() {
        "train" => cmd_train(args, &cfg),
        "local-sgd" => cmd_local_sgd(args, &cfg),
        "simulate" => cmd_simulate(args, &cfg),
        "tune" => cmd_tune(args, &cfg),
        "scale" => cmd_scale(args, &cfg),
        "sweep" => cmd_sweep(args, &cfg),
        "trace" => cmd_trace(args, &cfg),
        "analyze" => cmd_analyze(args, &cfg),
        "transport" => cmd_transport(args, &cfg),
        "obs" => cmd_obs(args),
        "lint" => cmd_lint(args),
        other => {
            eprintln!("unknown subcommand `{other}`\n{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args, cfg: &Config) -> Result<()> {
    let mut trainer = Trainer::new(cfg)?;
    let log = trainer.train()?;
    let mut t = Table::new(
        format!("train {} ({} workers)", cfg.train.model_size, cfg.cluster.workers),
        &["metric", "value"],
    );
    t.row(vec!["steps".into(), log.steps.len().to_string()]);
    t.row(vec!["final train loss".into(), f(log.final_loss(), 4)]);
    t.row(vec![
        "final eval loss".into(),
        f(log.summary["final_eval_loss"], 4),
    ]);
    t.row(vec!["mean drop rate".into(), pct(log.mean_drop_rate())]);
    t.row(vec!["virtual time (s)".into(), f(log.total_virtual_time(), 1)]);
    t.row(vec![
        "throughput (microbatch/s)".into(),
        f(log.throughput(), 2),
    ]);
    if let Some(tau) = trainer.threshold {
        t.row(vec!["threshold tau*".into(), f(tau, 3)]);
    }
    t.print();
    if let Some(out) = args.get("out") {
        let dir = PathBuf::from(out);
        log.write_csv(&dir.join("train.csv"))?;
        log.write_json(&dir.join("train.json"))?;
        println!("wrote {}/train.{{csv,json}}", dir.display());
    }
    Ok(())
}

fn cmd_local_sgd(args: &Args, cfg: &Config) -> Result<()> {
    let periods = args.usize_or("periods", 10)?;
    let tau = args.f64_or("tau", 0.0)?;
    let threshold = if tau > 0.0 { Some(tau) } else { None };
    let mut trainer = LocalSgdTrainer::new(cfg, threshold)?;
    let log = trainer.train(periods)?;
    let mut t = Table::new("local-sgd", &["metric", "value"]);
    t.row(vec!["periods".into(), periods.to_string()]);
    t.row(vec!["H (local steps)".into(), cfg.train.local_sgd_period.to_string()]);
    t.row(vec!["final loss".into(), f(log.final_loss(), 4)]);
    t.row(vec!["drop rate".into(), pct(log.mean_drop_rate())]);
    t.row(vec!["virtual time (s)".into(), f(log.total_virtual_time(), 1)]);
    t.print();
    Ok(())
}

/// Whether this invocation should attach an [`ObsRecorder`]: either
/// `--obs-out` on the command line or the `[obs]` config section.
fn obs_active(args: &Args, cfg: &Config) -> bool {
    args.get("obs-out").is_some() || cfg.obs.active()
}

/// File base for observability exports (`BASE.prom` / `BASE.json`):
/// `--obs-out` beats `[obs] out`; `[obs] enabled = true` with no `out`
/// records and prints the summary without writing files.
fn obs_base(args: &Args, cfg: &Config) -> Option<PathBuf> {
    if let Some(p) = args.get("obs-out") {
        return Some(PathBuf::from(p));
    }
    if !cfg.obs.out.is_empty() {
        return Some(PathBuf::from(&cfg.obs.out));
    }
    None
}

/// Write `BASE.prom` (Prometheus text exposition) and `BASE.json`
/// (snapshot), creating parent directories as needed.
fn write_obs_outputs(rec: &ObsRecorder, base: &std::path::Path) -> Result<()> {
    if let Some(dir) = base.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let prom = base.with_extension("prom");
    let json = base.with_extension("json");
    std::fs::write(&prom, dropcompute::obs::to_prometheus(rec))?;
    std::fs::write(&json, dropcompute::obs::to_json_snapshot(rec))?;
    println!("wrote {} and {}", prom.display(), json.display());
    Ok(())
}

/// Terminal summary of a recorder: step/drop totals, tail latency, and
/// the worst straggler by times-was-max.
fn print_obs_summary(rec: &ObsRecorder) {
    let mut t = Table::new("observability", &["metric", "value"]);
    t.row(vec!["steps".into(), rec.steps.to_string()]);
    t.row(vec![
        "microbatches".into(),
        format!(
            "{}/{} completed",
            rec.completed_microbatches, rec.scheduled_microbatches
        ),
    ]);
    t.row(vec![
        "drops (tau/ddl/phase/restart/fault)".into(),
        format!(
            "{}/{}/{}/{}/{}",
            rec.drops.tau_events,
            rec.drops.step_deadline,
            rec.drops.phase_checkpoint,
            rec.drops.survivor_restart,
            rec.drops.worker_fault
        ),
    ]);
    for (name, h) in [
        ("iter time", &rec.iter_time),
        ("compute time", &rec.compute_time),
        ("arrival offset", &rec.arrival_offset),
    ] {
        if h.count() == 0 {
            continue;
        }
        t.row(vec![
            format!("{name} p50/p90/p99/p99.9"),
            format!(
                "{:.4}/{:.4}/{:.4}/{:.4}",
                h.percentile(0.5),
                h.percentile(0.9),
                h.percentile(0.99),
                h.percentile(0.999)
            ),
        ]);
    }
    if let Some((w, s)) = rec
        .workers
        .iter()
        .enumerate()
        .max_by_key(|(_, s)| s.was_max)
    {
        t.row(vec![
            "worst straggler".into(),
            format!(
                "worker {w}: was-max {} dropped {} triggered-ckpt {}",
                s.was_max, s.dropped, s.triggered_checkpoint
            ),
        ]);
    }
    t.print();
}

/// `obs` subcommand: utilities over exported observability files.
fn cmd_obs(args: &Args) -> Result<()> {
    let action = args.positional.first().map(String::as_str).unwrap_or("");
    match action {
        "lint" => {
            let path = args.positional.get(1).ok_or_else(|| {
                dropcompute::util::Error::Cli(
                    "obs lint: expects a .prom file path".into(),
                )
            })?;
            let text = std::fs::read_to_string(path)?;
            let issues = dropcompute::obs::lint_prometheus(&text);
            if issues.is_empty() {
                println!("{path}: OK ({} lines)", text.lines().count());
                Ok(())
            } else {
                for i in &issues {
                    eprintln!("{path}: {i}");
                }
                Err(dropcompute::util::Error::Runtime(format!(
                    "obs lint: {} issue(s) in {path}",
                    issues.len()
                )))
            }
        }
        other => Err(dropcompute::util::Error::Cli(format!(
            "unknown obs action `{other}` (want lint <file.prom>)"
        ))),
    }
}

/// `lint` subcommand: the in-tree invariant lint engine
/// ([`dropcompute::lint`]). Walks a source root (default `rust/src`),
/// applies inline `lint:allow` suppressions and the checked-in
/// baseline, renders active findings, and under `--deny` exits
/// non-zero on any active deny-severity finding — the CI `lint-gate`.
/// `.prom` positionals run the `obs lint` exposition checker as a
/// sub-check whose issues also count toward the `--deny` gate.
fn cmd_lint(args: &Args) -> Result<()> {
    use dropcompute::lint::{self, Baseline};
    use dropcompute::util::Error;
    use std::path::Path;

    let mut prom_issues = 0usize;
    let mut prom_files = 0usize;
    let mut dir_pos: Option<&str> = None;
    for p in &args.positional {
        if p.ends_with(".prom") {
            prom_files += 1;
            let text = std::fs::read_to_string(p)?;
            let issues = dropcompute::obs::lint_prometheus(&text);
            for i in &issues {
                eprintln!("{p}: {i}");
            }
            if issues.is_empty() {
                println!("{p}: OK ({} lines)", text.lines().count());
            }
            prom_issues += issues.len();
        } else if dir_pos.is_none() {
            dir_pos = Some(p.as_str());
        } else {
            return Err(Error::Cli(format!(
                "lint: unexpected argument `{p}`"
            )));
        }
    }

    // a pure exposition-check invocation (`lint metrics.prom`) has no
    // tree to walk; anything else lints a source root
    let mut deny_findings = 0usize;
    if prom_files == 0 || dir_pos.is_some() || args.get("root").is_some() {
        let root = args
            .get("root")
            .or(dir_pos)
            .unwrap_or("rust/src");
        let root_path = Path::new(root);
        if !root_path.is_dir() {
            return Err(Error::Cli(format!(
                "lint: `{root}` is not a directory (pass a source root \
                 or run from the repo top level)"
            )));
        }
        let baseline_path = args.get("baseline").unwrap_or("lint-baseline.txt");
        let baseline = Baseline::load(Path::new(baseline_path))?;
        let report = lint::lint_root(root_path, baseline)?;

        if args.flag("update-baseline") {
            let n = report.active().count();
            std::fs::write(baseline_path, Baseline::format(report.active()))?;
            println!("lint: baselined {n} finding(s) into {baseline_path}");
            return Ok(());
        }

        let mut t = Table::new(
            format!("lint {root} ({} files)", report.files_scanned),
            &["rule", "sev", "location", "finding"],
        );
        for d in report.active() {
            t.row(vec![
                d.rule.to_string(),
                d.severity.name().to_string(),
                format!("{}:{}", d.file, d.line),
                d.message.clone(),
            ]);
        }
        t.print();
        println!(
            "lint: {} active ({} deny, {} warn); {} inline-allowed, \
             {} baselined",
            report.active().count(),
            report.active_deny(),
            report.active_warn(),
            report.suppressed(dropcompute::lint::Suppressed::Inline),
            report.suppressed(dropcompute::lint::Suppressed::Baseline),
        );

        if let Some(json) = args.get("json") {
            let jp = Path::new(json);
            if let Some(parent) = jp.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            std::fs::write(jp, report.to_json())?;
            println!("lint: wrote {json}");
        }
        deny_findings = report.active_deny();
    }

    if args.flag("deny") && deny_findings + prom_issues > 0 {
        return Err(Error::Runtime(format!(
            "lint: {} deny finding(s), {} exposition issue(s)",
            deny_findings, prom_issues
        )));
    }
    Ok(())
}

/// `transport` subcommand: the real-socket loopback harness
/// ([`dropcompute::transport`]).
fn cmd_transport(args: &Args, cfg: &Config) -> Result<()> {
    let action = args.positional.first().map(String::as_str).unwrap_or("run");
    match action {
        "run" => cmd_transport_run(args, cfg),
        "bench" => cmd_transport_bench(args, cfg),
        other => Err(dropcompute::util::Error::Cli(format!(
            "unknown transport action `{other}` (want run|bench)"
        ))),
    }
}

fn cmd_transport_run(args: &Args, cfg: &Config) -> Result<()> {
    use dropcompute::transport::{self, RunSpec, TransportKind};
    let mut spec = RunSpec::from_config(cfg)?;
    spec.iters = args.usize_or("iters", spec.iters as usize)? as u64;
    if let Some(k) = args.get("kind") {
        spec.kind = TransportKind::parse(k)?;
    }
    if let Some(t) = args.get("topology") {
        spec.topo = dropcompute::topology::TopologyKind::parse(t)?;
    }
    if let Some(p) = args.get("policy") {
        spec.policy = DropPolicy::parse(p)?;
    }
    if let Some(s) = args.get("scenario") {
        let plan = FaultPlan::parse(s)?;
        spec.plan = (!plan.is_empty()).then_some(plan);
    }
    spec.validate()?;

    let mut obs =
        obs_active(args, cfg).then(|| ObsRecorder::new(spec.workers));
    let report = transport::run_loopback(&spec, obs.as_mut())?;

    let mut t = Table::new(
        format!("transport run N={} M={}", spec.workers, spec.accums),
        &["metric", "value"],
    );
    t.row(vec![
        "transport".into(),
        format!("{} (real sockets)", spec.kind),
    ]);
    t.row(vec!["topology".into(), spec.topo.name().to_string()]);
    t.row(vec!["drop policy".into(), spec.policy.spec()]);
    if let Some(plan) = &spec.plan {
        t.row(vec!["scenario".into(), plan.spec()]);
    }
    t.row(vec!["steps".into(), report.steps.len().to_string()]);
    t.row(vec![
        "degraded steps".into(),
        report.stats.degraded_steps.to_string(),
    ]);
    t.row(vec![
        "excluded arrivals".into(),
        report.stats.excluded_arrivals.to_string(),
    ]);
    t.row(vec![
        "peers lost / recv timeouts".into(),
        format!("{}/{}", report.stats.peers_lost, report.stats.recv_timeouts),
    ]);
    t.row(vec![
        "retries (connect/send)".into(),
        format!(
            "{}/{}",
            report.stats.connect_retries, report.stats.send_retries
        ),
    ]);
    t.row(vec![
        "frames / bytes sent".into(),
        format!("{}/{}", report.stats.frames_sent, report.stats.bytes_sent),
    ]);
    t.print();

    // persist the recorded trace, then run the two acceptance gates:
    // bitwise replay (both sim timing paths agree on the recorded
    // draws) and sim-vs-real ordering conformance
    let trace_path =
        PathBuf::from(args.str_or("trace", &cfg.transport.trace_out));
    report.trace.save(&trace_path)?;
    println!("wrote {}", trace_path.display());
    let replayed = transport::replay_bitwise(&report.trace)?;
    println!("replay gate: {replayed} steps bitwise on both timing paths");
    println!("conformance: {}", report.conformance);

    if let Some(rec) = &obs {
        print_obs_summary(rec);
        if let Some(base) = obs_base(args, cfg) {
            write_obs_outputs(rec, &base)?;
        }
    }
    if !report.conformance.passed() {
        return Err(dropcompute::util::Error::Runtime(format!(
            "transport run: conformance gate failed ({})",
            report.conformance
        )));
    }
    Ok(())
}

/// Loopback all-reduce wall time per topology, real sockets vs the
/// in-process mpsc mesh (same schedules, same reduce discipline).
fn cmd_transport_bench(args: &Args, cfg: &Config) -> Result<()> {
    use dropcompute::collective::{topology_all_reduce, MeshComm};
    use dropcompute::topology::TopologyKind;
    use dropcompute::transport::{
        bind_mesh, transport_all_reduce, RetryPolicy, SocketMesh,
        TransportKind,
    };
    use std::time::{Duration, Instant};

    let smoke = args.flag("smoke");
    let iters = args.usize_or("iters", if smoke { 4 } else { 25 })?;
    let kind = match args.get("kind") {
        Some(k) => TransportKind::parse(k)?,
        None => cfg.transport.kind,
    };
    let n = cfg.cluster.workers.clamp(2, if smoke { 4 } else { 8 });
    let len = if smoke { 64 } else { cfg.transport.grad_len.max(64) };
    let deadline = Duration::from_secs_f64(cfg.transport.recv_deadline);

    let mut t = Table::new(
        format!("transport bench {kind} N={n} len={len} iters={iters}"),
        &["topology", "socket ms/op", "mpsc ms/op", "ratio"],
    );
    for topo in TopologyKind::ALL {
        // real sockets: one thread per rank, timed on rank 0
        let dir = std::env::temp_dir().join(format!(
            "dropcompute-bench-{}-{}",
            std::process::id(),
            topo.name()
        ));
        let (bindings, endpoints) = bind_mesh(kind, n, &dir)?;
        let eps: std::sync::Arc<Vec<_>> = std::sync::Arc::new(endpoints);
        let mut handles = Vec::new();
        for binding in bindings {
            let eps = std::sync::Arc::clone(&eps);
            handles.push(std::thread::spawn(move || -> Result<f64> {
                let rank = binding.rank;
                let mesh = SocketMesh::<f32>::establish(
                    binding,
                    &eps,
                    RetryPolicy::default(),
                    Duration::from_secs(10),
                )?;
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (rank + i) as f32).collect();
                // lint:allow(wall-clock): bench wall-time report, not a simulated timing path
                let start = Instant::now();
                for step in 0..iters {
                    transport_all_reduce(
                        &mesh,
                        topo,
                        step as u64,
                        &mut buf,
                        deadline,
                    )
                    .map_err(|e| {
                        dropcompute::util::Error::Runtime(format!(
                            "bench all-reduce: {e:?}"
                        ))
                    })?;
                }
                Ok(start.elapsed().as_secs_f64() / iters as f64)
            }));
        }
        let mut socket_secs = 0.0f64;
        for h in handles {
            let per_op = h.join().map_err(|_| {
                dropcompute::util::Error::Runtime(
                    "transport bench: worker panicked".into(),
                )
            })??;
            socket_secs = socket_secs.max(per_op);
        }
        if kind == TransportKind::Uds {
            std::fs::remove_dir_all(&dir).ok();
        }

        // mpsc mesh: same shape, in-process channels
        let comms = MeshComm::<f32>::full(n);
        let mut handles = Vec::new();
        for comm in comms {
            handles.push(std::thread::spawn(move || {
                let rank = comm.rank;
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (rank + i) as f32).collect();
                // lint:allow(wall-clock): bench wall-time report, not a simulated timing path
                let start = Instant::now();
                for _ in 0..iters {
                    topology_all_reduce(&comm, topo, &mut buf);
                }
                start.elapsed().as_secs_f64() / iters as f64
            }));
        }
        let mut mpsc_secs = 0.0f64;
        for h in handles {
            let per_op = h.join().map_err(|_| {
                dropcompute::util::Error::Runtime(
                    "transport bench: mpsc worker panicked".into(),
                )
            })?;
            mpsc_secs = mpsc_secs.max(per_op);
        }

        t.row(vec![
            topo.name().to_string(),
            f(socket_secs * 1e3, 3),
            f(mpsc_secs * 1e3, 3),
            f(socket_secs / mpsc_secs.max(1e-12), 2),
        ]);
    }
    t.print();
    Ok(())
}

/// Apply `--topology` / `--comm-drop-deadline` overrides to a cluster
/// config (shared by `simulate` and `scale`).
fn comm_overrides(
    args: &Args,
    cluster: &mut dropcompute::config::ClusterConfig,
) -> Result<()> {
    if let Some(spec) = args.get("topology") {
        // "fixed" mirrors the comm.topology config key: back to the
        // paper's fixed-T^c model (e.g. to override a config file).
        cluster.topology = if spec == "fixed" {
            None
        } else {
            Some(dropcompute::topology::TopologyKind::parse(spec)?)
        };
    }
    cluster.comm_drop_deadline =
        args.f64_or("comm-drop-deadline", cluster.comm_drop_deadline)?;
    // legacy single-restart per-phase semantics (the default is the
    // recursive re-check; see ClusterSim::with_single_restart)
    if args.flag("single-restart") {
        cluster.single_restart = true;
    }
    Ok(())
}

fn cmd_simulate(args: &Args, cfg: &Config) -> Result<()> {
    let iters = args.usize_or("iters", 100)?;
    let tau = args.f64_or("tau", 0.0)?;
    let mut cluster = cfg.cluster.clone();
    comm_overrides(args, &mut cluster)?;
    // one drop surface: an explicit --policy replaces the config-level
    // policy ([policy] spec, which itself replaces the [comm]
    // deadline); the legacy --tau and --comm-drop-deadline flags
    // compose on top of whichever applies, as the help text promises
    let flag_deadline = args.f64_or("comm-drop-deadline", 0.0)?;
    let (mut policy, deadline_folded) = match args.get("policy") {
        Some(spec) => (DropPolicy::parse(spec)?, false),
        None => match &cfg.policy {
            Some(p) => (p.clone(), false),
            // from_cluster reads cluster.comm_drop_deadline, which
            // comm_overrides already updated from the flag
            None => (DropPolicy::from_cluster(&cluster), true),
        },
    };
    if !deadline_folded && flag_deadline > 0.0 {
        policy = policy.and(DropPolicy::comm_deadline(flag_deadline));
    }
    if tau > 0.0 {
        policy = policy.and(DropPolicy::compute_tau(tau));
    }
    // fault scenario: --scenario flag replaces the [scenario] config
    // section; `none` (the empty plan) disables either
    let scenario = match args.get("scenario") {
        Some(spec) => {
            let plan = FaultPlan::parse(spec)?;
            (!plan.is_empty()).then_some(plan)
        }
        None => cfg.scenario.clone(),
    };
    let batch = args.usize_or("batch", 1)?.max(1);
    if let Some(plan) = &scenario {
        plan.validate_for(cluster.workers)?;
        plan.validate_horizon(iters as u64)?;
    }
    // --batch S: S replicas (seeds seed..seed+S-1) step in SoA lockstep
    // through one shared compiled phase pass; each replica's outcomes
    // are bitwise what a solo run with its seed would produce, so the
    // aggregate below is just an S-replica average of solo runs.
    let mut sims = Vec::with_capacity(batch);
    for r in 0..batch as u64 {
        let mut sim = ClusterSim::new(&cluster, cfg.train.seed + r)
            .with_policy(policy.clone());
        if let Some(plan) = &scenario {
            sim = sim.with_fault_plan(plan.clone());
        }
        sims.push(sim);
    }
    let mut rb = ReplicaBatch::from_sims(sims);
    let mut outs =
        vec![dropcompute::sim::StepOutcome::default(); batch];
    let mut iter_w = dropcompute::stats::Welford::new();
    let mut completed = 0usize;
    let mut recs = obs_active(args, cfg)
        .then(|| {
            (0..batch)
                .map(|_| ObsRecorder::new(cluster.workers))
                .collect::<Vec<_>>()
        });
    for _ in 0..iters {
        match recs.as_mut() {
            Some(rs) => rb.step_installed_observed(&mut outs, rs),
            None => rb.step_installed_into(&mut outs),
        }
        for out in &outs {
            iter_w.push(out.iter_time);
            completed += out.total_completed();
        }
    }
    // replica recorders merge in replica order — deterministic, and
    // for --batch 1 bitwise identical to the unbatched recorder
    let obs = recs.map(|rs| {
        let mut it = rs.into_iter();
        let mut merged = it.next().expect("batch >= 1");
        for rec in it {
            merged.merge(&rec);
        }
        merged
    });
    // a Local-SGD policy schedules one micro-batch per local step
    let per_iter =
        policy.local_sgd_h().unwrap_or(cfg.cluster.accumulations);
    let scheduled = iters * batch * cfg.cluster.workers * per_iter;
    let mut t = Table::new(
        format!("simulate N={} M={}", cfg.cluster.workers, cfg.cluster.accumulations),
        &["metric", "value"],
    );
    t.row(vec![
        "comm model".into(),
        match cluster.topology {
            Some(kind) => format!("{} (event-driven)", kind.name()),
            None => format!("fixed T^c = {:.3}s", cluster.comm_latency),
        },
    ]);
    t.row(vec!["drop policy".into(), policy.spec()]);
    if let Some(plan) = &scenario {
        t.row(vec!["scenario".into(), plan.spec()]);
    }
    if batch > 1 {
        t.row(vec!["batched replicas".into(), batch.to_string()]);
    }
    t.row(vec!["iterations".into(), iters.to_string()]);
    t.row(vec!["mean iter time".into(), f(iter_w.mean(), 3)]);
    t.row(vec!["iter time std".into(), f(iter_w.std(), 3)]);
    t.row(vec!["min/max".into(), format!("{:.3}/{:.3}", iter_w.min(), iter_w.max())]);
    t.row(vec![
        "drop rate".into(),
        pct(1.0 - completed as f64 / scheduled as f64),
    ]);
    t.row(vec![
        "throughput (mb/s)".into(),
        f(completed as f64 / (iter_w.mean() * (iters * batch) as f64), 2),
    ]);
    t.print();
    if let Some(rec) = &obs {
        print_obs_summary(rec);
        if let Some(base) = obs_base(args, cfg) {
            write_obs_outputs(rec, &base)?;
        }
    }
    Ok(())
}

fn cmd_tune(args: &Args, cfg: &Config) -> Result<()> {
    let iters = args.usize_or("iters", cfg.dropcompute.calibration_iters)?;
    let grid = args.usize_or("grid", cfg.dropcompute.search_points)?;
    let mut sim = ClusterSim::new(&cfg.cluster, cfg.train.seed);
    let trace = sim.record_trace(iters);
    let choice = analysis::choose_threshold(&trace, grid);
    let mut t = Table::new(
        "Algorithm 2 threshold sweep",
        &["tau", "S_eff", "completion", "step speedup", "drop"],
    );
    let stride = (choice.sweep.len() / 16).max(1);
    for p in choice.sweep.iter().step_by(stride) {
        t.row(vec![
            f(p.tau, 3),
            f(p.effective_speedup, 4),
            pct(p.completion_rate),
            f(p.step_speedup, 4),
            pct(p.drop_rate),
        ]);
    }
    t.print();
    println!(
        "tau* = {:.3}  predicted speedup {:.4}  completion {:.1}%",
        choice.tau,
        choice.speedup,
        choice.completion_rate * 100.0
    );
    Ok(())
}

/// `--key a,b,c` as a typed list, falling back to the config's values.
fn csv_list<T: std::str::FromStr>(
    args: &Args,
    key: &str,
    fallback: &[T],
) -> Result<Vec<T>>
where
    T: Clone,
{
    match args.get(key) {
        None => Ok(fallback.to_vec()),
        Some(raw) => {
            let parsed: Vec<T> = raw
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().map_err(|_| {
                        dropcompute::util::Error::Cli(format!(
                            "--{key}: bad entry `{s}`"
                        ))
                    })
                })
                .collect::<Result<_>>()?;
            if parsed.is_empty() {
                return Err(dropcompute::util::Error::Cli(format!(
                    "--{key}: empty list `{raw}`"
                )));
            }
            Ok(parsed)
        }
    }
}

fn cmd_scale(args: &Args, cfg: &Config) -> Result<()> {
    let workers =
        csv_list::<usize>(args, "workers", &[8, 16, 32, 64, 128, 200])?;
    let mut base = cfg.cluster.clone();
    comm_overrides(args, &mut base)?;
    let jobs = args.usize_or("jobs", cfg.sweep.jobs)?;
    let run = ScaleRun { base, jobs, ..Default::default() };
    let pts = run.sweep(&workers);
    let mut t = Table::new(
        "scale sweep (Fig 1 style)",
        &["N", "baseline mb/s", "DropCompute mb/s", "linear", "tau*", "drop"],
    );
    for p in &pts {
        t.row(vec![
            p.workers.to_string(),
            f(p.baseline_throughput, 1),
            f(p.dropcompute_throughput, 1),
            f(p.linear_throughput, 1),
            f(p.tau, 2),
            pct(p.drop_rate),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: &Config) -> Result<()> {
    let mut cluster = cfg.cluster.clone();
    comm_overrides(args, &mut cluster)?;
    let sc = &cfg.sweep;
    let workers = csv_list::<usize>(args, "workers", &sc.workers)?;
    let thresholds = csv_list::<f64>(args, "thresholds", &sc.thresholds)?;
    // policy axis precedence: repeated --policy flags, else the
    // `[policy] sweep` config axis — unless explicit legacy axis flags
    // (--thresholds/--deadlines) override it, so no explicit flag is
    // ever silently discarded. When active, the policy axis subsumes
    // the thresholds/deadlines axes entirely.
    let policy_args = args.get_all("policy");
    let legacy_axis_flags =
        args.get("thresholds").is_some() || args.get("deadlines").is_some();
    let policies: Vec<DropPolicy> = if !policy_args.is_empty() {
        policy_args
            .iter()
            .map(|s| DropPolicy::parse(s))
            .collect::<Result<_>>()?
    } else if legacy_axis_flags {
        Vec::new()
    } else {
        sc.policies.clone()
    };
    // deadline axis precedence: explicit --deadlines, else a non-zero
    // cluster deadline (from --comm-drop-deadline or the [comm] config
    // key) pins the axis to that one value — neither source may be
    // silently ignored — else the [sweep] config axis.
    let deadlines = match args.get("deadlines") {
        Some(_) => csv_list::<f64>(args, "deadlines", &sc.deadlines)?,
        None if cluster.comm_drop_deadline > 0.0 && sc.deadlines == [0.0] => {
            vec![cluster.comm_drop_deadline]
        }
        None => sc.deadlines.clone(),
    };
    let seeds = csv_list::<u64>(args, "seeds", &sc.seeds)?;
    // scenario (churn) axis precedence mirrors the policy axis:
    // repeated --scenario flags, else the `[scenario] sweep` config
    // list. Events naming workers beyond a point's cluster size are
    // inert there, so one axis composes with any worker axis.
    let scenario_args = args.get_all("scenario");
    let scenarios: Vec<FaultPlan> = if !scenario_args.is_empty() {
        scenario_args
            .iter()
            .map(|s| FaultPlan::parse(s))
            .collect::<Result<_>>()?
    } else {
        sc.scenarios.clone()
    };
    // same range rule the [sweep] config section enforces
    if thresholds.iter().any(|&t| t < 0.0) || deadlines.iter().any(|&d| d < 0.0)
    {
        return Err(dropcompute::util::Error::Cli(
            "--thresholds and --deadlines must be >= 0".into(),
        ));
    }
    let spec = dropcompute::sweep::SweepSpec::new(cluster)
        .workers(&workers)
        .thresholds(&thresholds)
        .deadlines(&deadlines)
        .policies(&policies)
        .scenarios(&scenarios)
        .seeds(&seeds)
        .iters(args.usize_or("iters", sc.iters)?)
        .jobs(args.usize_or("jobs", sc.jobs)?)
        .batch(args.usize_or("batch", sc.batch)?)
        .progress(sc.progress && !args.flag("quiet"));
    let n = spec.len();
    let jobs = dropcompute::sweep::resolve_jobs(spec.jobs);
    let scen_note = if scenarios.is_empty() {
        String::new()
    } else {
        format!("{} scenarios x ", scenarios.len())
    };
    if policies.is_empty() {
        println!(
            "sweep: {} points ({} workers x {} thresholds x {} deadlines x \
             {scen_note}{} seeds), {} iters each, {jobs} jobs",
            n,
            workers.len(),
            thresholds.len(),
            deadlines.len(),
            seeds.len(),
            spec.iters,
        );
    } else {
        println!(
            "sweep: {} points ({} workers x {} policies x {scen_note}\
             {} seeds), {} iters each, {jobs} jobs",
            n,
            workers.len(),
            policies.len(),
            seeds.len(),
            spec.iters,
        );
    }
    // lint:allow(wall-clock): CLI wall-time report, not a simulated timing path
    let t0 = std::time::Instant::now();
    let (result, sweep_obs) = if obs_active(args, cfg) {
        let (r, o) = spec.run_observed();
        (r, Some(o))
    } else {
        (spec.run(), None)
    };
    let secs = t0.elapsed().as_secs_f64();
    let policy_axis = !policies.is_empty();
    let mut t = if policy_axis {
        Table::new(
            "scenario grid",
            &["N", "policy", "seed", "iter time", "mb/s", "drop"],
        )
    } else {
        Table::new(
            "scenario grid",
            &["N", "tau", "deadline", "seed", "iter time", "mb/s", "drop"],
        )
    };
    // keep terminal output bounded on huge grids; the JSON has all points
    let stride = (result.points.len() / 48).max(1);
    for p in result.points.iter().step_by(stride) {
        if policy_axis {
            t.row(vec![
                p.workers.to_string(),
                p.policy.clone().unwrap_or_else(|| "none".into()),
                p.seed.to_string(),
                f(p.mean_iter_time, 3),
                f(p.throughput, 1),
                pct(p.drop_rate),
            ]);
        } else {
            t.row(vec![
                p.workers.to_string(),
                f(p.threshold, 2),
                f(p.deadline, 2),
                p.seed.to_string(),
                f(p.mean_iter_time, 3),
                f(p.throughput, 1),
                pct(p.drop_rate),
            ]);
        }
    }
    t.print();
    if stride > 1 {
        println!("(showing every {stride}-th of {} points)", result.points.len());
    }
    println!(
        "{} points in {:.2}s ({:.1} points/s)",
        result.points.len(),
        secs,
        result.points.len() as f64 / secs.max(1e-9),
    );
    if let Some(out) = args.get("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("sweep.json");
        std::fs::write(&path, result.to_json())?;
        println!("wrote {}", path.display());
    }
    if let Some(o) = &sweep_obs {
        print_obs_summary(&o.merged);
        if let Some(base) = obs_base(args, cfg) {
            write_obs_outputs(&o.merged, &base)?;
            // per-point snapshots, one JSON object per grid point in
            // enumeration order
            let pts = o
                .per_point
                .iter()
                .map(dropcompute::obs::to_json_snapshot)
                .collect::<Vec<_>>()
                .join(",\n");
            let path = base.with_extension("points.json");
            std::fs::write(&path, format!("[\n{pts}\n]\n"))?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn cmd_trace(args: &Args, cfg: &Config) -> Result<()> {
    use dropcompute::sim::{StepOutcome, TraceRecord};
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("record");
    let path = PathBuf::from(args.str_or("trace", &cfg.trace.path));
    match action {
        "record" => {
            let iters = args.usize_or("iters", cfg.trace.iters)?;
            if iters == 0 {
                return Err(dropcompute::util::Error::Cli(
                    "trace record: --iters must be >= 1".into(),
                ));
            }
            let mut cluster = cfg.cluster.clone();
            comm_overrides(args, &mut cluster)?;
            let policy = match args.get("policy") {
                Some(spec) => DropPolicy::parse(spec)?,
                None => match &cfg.policy {
                    Some(p) => p.clone(),
                    None => DropPolicy::from_cluster(&cluster),
                },
            };
            let mut sim = ClusterSim::new(&cluster, cfg.train.seed)
                .with_policy(policy.clone());
            // churn recording: the plan rides in the trace meta so
            // replay restores the exact membership history
            let scenario = match args.get("scenario") {
                Some(spec) => {
                    let plan = FaultPlan::parse(spec)?;
                    (!plan.is_empty()).then_some(plan)
                }
                None => cfg.scenario.clone(),
            };
            if let Some(plan) = &scenario {
                plan.validate_for(cluster.workers)?;
                plan.validate_horizon(iters as u64)?;
                sim = sim.with_fault_plan(plan.clone());
            }
            sim.start_recording();
            let mut out = dropcompute::sim::StepOutcome::default();
            let mut t_sum = 0.0;
            for _ in 0..iters {
                sim.step_installed_into(&mut out);
                t_sum += out.iter_time;
            }
            let trace = sim.finish_recording()?;
            trace.save(&path)?;
            let mut t = Table::new("trace record", &["metric", "value"]);
            t.row(vec!["steps".into(), iters.to_string()]);
            t.row(vec![
                "cluster".into(),
                format!("N={} M={}", cluster.workers, cluster.accumulations),
            ]);
            t.row(vec!["policy".into(), policy.spec()]);
            if let Some(plan) = &scenario {
                t.row(vec!["scenario".into(), plan.spec()]);
            }
            t.row(vec!["mean iter time".into(), f(t_sum / iters as f64, 3)]);
            t.print();
            println!("wrote {}", path.display());
            Ok(())
        }
        "replay" => {
            let trace = TraceRecord::load(&path)?;
            let override_policy = match args.get("policy") {
                Some(spec) => Some(DropPolicy::parse(spec)?),
                None => None,
            };
            let mut sim = ClusterSim::from_trace(&trace)?;
            if args.flag("reference") {
                sim = sim.with_reference_timing();
            }
            if let Some(p) = &override_policy {
                sim.set_policy(p);
            }
            let mut out = StepOutcome::default();
            let mut t_sum = 0.0;
            let mut completed = 0usize;
            let mut conform = 0usize;
            let mut obs = obs_active(args, cfg)
                .then(|| ObsRecorder::new(trace.meta.workers));
            for i in 0..trace.len() {
                match obs.as_mut() {
                    Some(rec) => sim.replay_observed(&mut out, rec)?,
                    None => sim.replay_into(&mut out)?,
                }
                t_sum += out.iter_time;
                completed += out.total_completed();
                if override_policy.is_none()
                    && trace.outcomes.get(i).is_some_and(|o| o.matches(&out))
                {
                    conform += 1;
                }
            }
            let scheduled =
                trace.len() * trace.meta.workers * trace.meta.accums;
            let mut t = Table::new("trace replay", &["metric", "value"]);
            t.row(vec![
                "timing path".into(),
                if args.flag("reference") {
                    "event-queue oracle".into()
                } else {
                    "compiled".into()
                },
            ]);
            t.row(vec![
                "policy".into(),
                override_policy
                    .as_ref()
                    .map(DropPolicy::spec)
                    .unwrap_or_else(|| trace.meta.policy.clone()),
            ]);
            t.row(vec!["steps".into(), trace.len().to_string()]);
            t.row(vec![
                "mean iter time".into(),
                f(t_sum / trace.len().max(1) as f64, 3),
            ]);
            t.row(vec![
                "drop rate".into(),
                pct(1.0 - completed as f64 / scheduled.max(1) as f64),
            ]);
            if override_policy.is_none() {
                t.row(vec![
                    "conformance".into(),
                    format!("{conform}/{} steps bitwise", trace.len()),
                ]);
            }
            t.print();
            if let Some(rec) = &obs {
                print_obs_summary(rec);
                if let Some(base) = obs_base(args, cfg) {
                    write_obs_outputs(rec, &base)?;
                }
            }
            if override_policy.is_none()
                && !trace.outcomes.is_empty()
                && conform != trace.len()
            {
                return Err(dropcompute::util::Error::Runtime(format!(
                    "replay diverged from the recorded outcomes \
                     ({conform}/{} steps bitwise)",
                    trace.len()
                )));
            }
            Ok(())
        }
        "fit" => {
            let trace = TraceRecord::load(&path)?;
            let grid = args.usize_or("grid", cfg.trace.fit_grid)?;
            let fit = dropcompute::analysis::fit_budgets(
                &trace,
                grid,
                cfg.trace.fit_deadlines,
            )?;
            let mut t = Table::new(
                "trace fit (Algorithm-2 analogue, replay-measured)",
                &["candidate", "spec", "S_eff", "completion", "iter time"],
            );
            for (label, e) in [
                ("step-level", &fit.step_level),
                ("deadline", &fit.deadline_level),
                ("per-phase", &fit.per_phase),
                ("best", &fit.best),
            ] {
                t.row(vec![
                    label.into(),
                    e.spec.clone(),
                    f(e.speedup, 4),
                    pct(e.completion),
                    f(e.mean_iter_time, 3),
                ]);
            }
            t.print();
            if fit.censored {
                println!(
                    "WARNING: trace was recorded under `{}` — its samples \
                     are censored at that compute threshold, so speedups \
                     are relative to the recorded policy, not a true \
                     no-drop baseline (record without a tau clause for \
                     absolute numbers)",
                    trace.meta.policy
                );
            }
            println!(
                "fitted policy spec: {}  (predicted speedup {:.4} over {} \
                 candidates, baseline iter {:.3}s)",
                fit.best.spec,
                fit.best.speedup,
                fit.evaluated.len(),
                fit.baseline_iter_time,
            );
            Ok(())
        }
        other => Err(dropcompute::util::Error::Cli(format!(
            "unknown trace action `{other}` (want record, replay or fit)"
        ))),
    }
}

fn cmd_analyze(args: &Args, cfg: &Config) -> Result<()> {
    let model = dropcompute::sim::LatencyModel::from_config(&cfg.cluster);
    let s = Setting {
        workers: cfg.cluster.workers,
        accums: cfg.cluster.accumulations,
        mu: model.mean(),
        sigma2: model.variance(),
        comm: cfg.cluster.comm_latency,
    };
    let e_t = s.expected_step_time();
    let (tau_star, speed) = s.optimal_threshold(512);
    let tau = args.f64_or("tau", tau_star)?;
    let mut t = Table::new("analytical model (Eq. 4/5/11)", &["quantity", "value"]);
    t.row(vec!["mu (microbatch mean)".into(), f(s.mu, 4)]);
    t.row(vec!["sigma^2".into(), f(s.sigma2, 5)]);
    t.row(vec!["E[T] baseline".into(), f(e_t, 3)]);
    t.row(vec!["E[T] single worker".into(), f(s.accums as f64 * s.mu, 3)]);
    t.row(vec!["E[M~](tau)".into(), f(s.expected_completed(tau), 3)]);
    t.row(vec!["S_eff(tau)".into(), f(s.effective_speedup(tau), 4)]);
    t.row(vec!["tau*".into(), f(tau_star, 3)]);
    t.row(vec!["S_eff(tau*)".into(), f(speed, 4)]);
    t.row(vec![
        "drop rate at tau*".into(),
        pct(s.drop_rate(tau_star)),
    ]);
    t.print();
    Ok(())
}
