//! API-compatible stub of the `xla-rs` PJRT bindings.
//!
//! The sandbox build has no network registry and no PJRT shared library,
//! so this in-tree crate provides the exact API surface that
//! `dropcompute::runtime::pjrt` consumes. Every operation that would
//! require a real backend returns [`Error::Unavailable`]; the sim,
//! collective, topology and analysis layers (which never touch PJRT)
//! compile and run unaffected. Swap the `xla` path dependency in the
//! workspace `Cargo.toml` for real xla-rs bindings to execute the
//! layer-1 HLO artifacts.

use std::fmt;

/// Stub error: every backend-requiring call returns this.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT backend unavailable (in-tree xla stub; \
                 link real xla-rs bindings to execute HLO artifacts)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// One PJRT device (stub: carries no state).
#[derive(Debug, Clone, Copy)]
pub struct Device;

/// An HLO module parsed from text (stub: never constructed).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    devices: Vec<Device>,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&Device>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}
