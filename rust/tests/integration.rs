//! Cross-module integration tests: the full stack composed end-to-end.

use dropcompute::analysis::{choose_threshold, Setting};
use dropcompute::config::{
    Compensation, Config, NoiseKind, StragglerKind, ThresholdPolicy,
};
use dropcompute::coordinator::{decentralized_calibration, ScaleRun};
use dropcompute::sim::{ClusterSim, CommModel, LatencyModel};
use dropcompute::train::{GradNorm, LocalSgdTrainer, Trainer};

fn paper_noise() -> NoiseKind {
    NoiseKind::PaperLogNormal {
        mu: 4.0,
        sigma: 1.0,
        alpha: 2.0 * (4.5f64).exp(),
        beta: 5.5,
    }
}

fn tiny_training_config() -> Config {
    let mut cfg = Config::default();
    cfg.train.model_size = "test".into();
    cfg.train.steps = 15;
    cfg.train.lr = 2.5e-3;
    cfg.train.log_every = 10_000;
    cfg.cluster.workers = 6;
    cfg.cluster.accumulations = 4;
    cfg.cluster.noise = paper_noise();
    cfg
}

/// The headline composition: noisy cluster -> Algorithm 2 -> DropCompute
/// training is faster per useful sample than the baseline AND converges.
#[test]
fn end_to_end_dropcompute_beats_baseline_throughput() {
    dropcompute::util::set_verbosity(0);
    let mut base_cfg = tiny_training_config();
    base_cfg.dropcompute.policy = ThresholdPolicy::Off;
    let base = Trainer::new(&base_cfg).unwrap().train().unwrap();

    let mut dc_cfg = tiny_training_config();
    dc_cfg.dropcompute.policy = ThresholdPolicy::Auto;
    let dc = Trainer::new(&dc_cfg).unwrap().train().unwrap();

    assert!(dc.throughput() > base.throughput(),
        "useful throughput: dc {} vs base {}", dc.throughput(), base.throughput());
    assert!(dc.final_loss() < dc.steps[0].loss, "dc run must converge");
    assert!(dc.mean_drop_rate() > 0.0 && dc.mean_drop_rate() < 0.5);
}

/// Trainer + every compensation mode runs and converges.
#[test]
fn all_compensation_modes_run() {
    dropcompute::util::set_verbosity(0);
    for comp in [
        Compensation::None,
        Compensation::ExtraSteps,
        Compensation::IncreasedBatch,
        Compensation::Resample,
    ] {
        let mut cfg = tiny_training_config();
        cfg.train.steps = 8;
        cfg.dropcompute.policy = ThresholdPolicy::TargetDropRate(0.12);
        cfg.dropcompute.compensation = comp;
        let log = Trainer::new(&cfg).unwrap().train().unwrap();
        assert!(log.final_loss().is_finite(), "{comp:?}");
    }
}

/// Both gradient normalizations train.
#[test]
fn both_grad_norms_train() {
    dropcompute::util::set_verbosity(0);
    for norm in [GradNorm::Computed, GradNorm::Scheduled] {
        let mut cfg = tiny_training_config();
        cfg.train.steps = 8;
        cfg.dropcompute.policy = ThresholdPolicy::TargetDropRate(0.1);
        let mut t = Trainer::new(&cfg).unwrap();
        t.norm = norm;
        let log = t.train().unwrap();
        assert!(log.final_loss().is_finite());
    }
}

/// CLI -> config -> trainer plumbing.
#[test]
fn cli_config_roundtrip_drives_trainer() {
    dropcompute::util::set_verbosity(0);
    let spec = dropcompute::cli::Spec::new()
        .subcommands(&["train"])
        .value_keys(&["set", "config"]);
    let args = spec
        .parse([
            "train",
            "--set", "train.model_size=\"test\"",
            "--set", "train.steps=5",
            "--set", "train.log_every=1000",
            "--set", "cluster.workers=3",
            "--set", "cluster.accumulations=2",
            "--set", "dropcompute.policy=\"fixed\"",
            "--set", "dropcompute.threshold=2.0",
        ])
        .unwrap();
    let cfg = args.build_config().unwrap();
    assert_eq!(cfg.cluster.workers, 3);
    let mut t = Trainer::new(&cfg).unwrap();
    let log = t.train().unwrap();
    assert_eq!(t.threshold, Some(2.0));
    assert_eq!(log.steps.len(), 5);
}

/// Config file on disk -> trainer.
#[test]
fn config_file_loads() {
    let doc = dropcompute::config::Document::load(std::path::Path::new(
        "configs/bert_like_pretrain.toml",
    ))
    .unwrap();
    let cfg = Config::from_doc(&doc).unwrap();
    assert_eq!(cfg.cluster.accumulations, 12);
    assert_eq!(cfg.dropcompute.policy, ThresholdPolicy::Auto);
    assert!(matches!(cfg.cluster.noise, NoiseKind::PaperLogNormal { .. }));
}

/// Decentralized Algorithm 2 over the real ring == centralized result,
/// at a size comparable to the paper's cluster.
#[test]
fn decentralized_calibration_at_scale() {
    let cfg = dropcompute::config::ClusterConfig {
        workers: 48,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.5,
        noise: paper_noise(),
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, 99);
    let trace = sim.record_trace(6);
    let choices = decentralized_calibration(&trace, 64);
    let central = choose_threshold(&trace, 64);
    for c in &choices {
        assert_eq!(c.tau.to_bits(), central.tau.to_bits());
    }
}

/// Ring-comm timing model + analytical model compose: the emergent T^c
/// feeds Eq. 11 sensibly.
#[test]
fn ring_comm_model_feeds_analysis() {
    let comm = CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 };
    let tc = comm.serial_latency(64);
    assert!(tc > 0.0 && tc < 1.0);
    let s = Setting { workers: 64, accums: 12, mu: 0.45, sigma2: 0.05, comm: tc };
    let (tau, speed) = s.optimal_threshold(128);
    assert!(tau > 0.0 && speed >= 1.0);
}

/// Local-SGD under single-server stragglers: DropCompute strictly
/// reduces the period time (App. B.3's harder scenario).
#[test]
fn local_sgd_single_server_stragglers() {
    dropcompute::util::set_verbosity(0);
    let mut cfg = tiny_training_config();
    cfg.train.local_sgd_period = 3;
    cfg.cluster.noise = NoiseKind::None;
    cfg.cluster.stragglers =
        StragglerKind::SingleServer { p: 0.5, delay: 1.5, server_size: 2 };
    let plain = LocalSgdTrainer::new(&cfg, None).unwrap().train(4).unwrap();
    let dc = LocalSgdTrainer::new(&cfg, Some(0.9)).unwrap().train(4).unwrap();
    assert!(dc.total_virtual_time() < plain.total_virtual_time());
}

/// Failure injection: a worker whose compute stalls mid-run freezes the
/// baseline, while DropCompute training proceeds on the survivors and
/// still converges (graceful degradation, §2).
#[test]
fn dropcompute_survives_compute_stall() {
    dropcompute::util::set_verbosity(0);
    let mut cfg = tiny_training_config();
    cfg.train.steps = 10;
    cfg.cluster.noise = NoiseKind::None;
    cfg.cluster.stragglers = StragglerKind::Fatal { worker: 1, from_step: 4 };
    cfg.dropcompute.policy = ThresholdPolicy::Fixed(2.5);
    let mut t = Trainer::new(&cfg).unwrap();
    let log = t.train().unwrap();
    // before the stall every worker contributes; after, worker 1 is gone
    assert_eq!(log.steps[0].completed_microbatches, 6 * 4);
    let after = &log.steps[6];
    assert_eq!(after.completed_microbatches, 5 * 4);
    assert!(after.iter_time < 3.5, "step time stays capped at tau + T^c");
    assert!(log.final_loss() < log.steps[0].loss, "still converges");

    // the baseline would stall: its simulated iteration takes ~forever
    let mut base_cfg = cfg.clone();
    base_cfg.dropcompute.policy = ThresholdPolicy::Off;
    let mut sim = dropcompute::sim::ClusterSim::new(&base_cfg.cluster, 0);
    for _ in 0..5 {
        sim.step(None);
    }
    assert!(sim.step(None).iter_time > 1e8);
}

/// Checkpoint round-trip through the real trainer.
#[test]
fn checkpoint_restores_training_state() {
    dropcompute::util::set_verbosity(0);
    use dropcompute::train::Checkpoint;
    let cfg = tiny_training_config();
    let mut t = Trainer::new(&cfg).unwrap();
    let _ = t.train().unwrap();
    let dir = std::env::temp_dir().join("dc_integration_ckpt");
    let path = dir.join("final.dckp");
    Checkpoint::from_params(&t.runtime.manifest, &t.params, 15, 0, 0.0)
        .save(&path)
        .unwrap();
    let restored = Checkpoint::load(&path)
        .unwrap()
        .into_params(&t.runtime.manifest)
        .unwrap();
    assert_eq!(restored.tensors(), t.params.tensors());
    std::fs::remove_dir_all(&dir).ok();
}

/// The scale runner's emergent numbers stay within physical bounds.
#[test]
fn scale_run_sanity() {
    let run = ScaleRun {
        base: dropcompute::config::ClusterConfig {
            workers: 1,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: paper_noise(),
            ..Default::default()
        },
        calibration_iters: 8,
        measure_iters: 25,
        grid: 64,
        seed: 3,
        ..ScaleRun::default()
    };
    let p = run.point(32);
    assert!(p.dropcompute_throughput <= p.linear_throughput * 1.02);
    assert!(p.tau > 0.0);
}

/// LatencyModel moments drive Setting: analytic speedup sits in the same
/// ballpark as the trace-based Algorithm 2 prediction.
#[test]
fn analytic_and_empirical_agree_on_benefit() {
    let cfg = dropcompute::config::ClusterConfig {
        workers: 32,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.5,
        noise: paper_noise(),
        ..Default::default()
    };
    let model = LatencyModel::from_config(&cfg);
    let s = Setting {
        workers: 32,
        accums: 12,
        mu: model.mean(),
        sigma2: model.variance(),
        comm: 0.5,
    };
    let (_, analytic) = s.optimal_threshold(128);
    let mut sim = ClusterSim::new(&cfg, 5);
    let trace = sim.record_trace(25);
    let empirical = choose_threshold(&trace, 128).speedup;
    assert!(
        (analytic - empirical).abs() < 0.15,
        "analytic {analytic} vs empirical {empirical}"
    );
}

/// The topology subsystem end-to-end: a hierarchical event-driven
/// collective + bounded-wait DropComm membership flow through
/// ClusterSim and ScaleRun, and the numbers stay physical.
#[test]
fn topology_scale_run_end_to_end() {
    use dropcompute::topology::TopologyKind;
    let base = dropcompute::config::ClusterConfig {
        workers: 1,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: paper_noise(),
        topology: Some(TopologyKind::Hierarchical { group: 0 }),
        link_latency: 25e-6,
        link_bandwidth: 12.5e9,
        grad_bytes: 4e6,
        ..Default::default()
    };
    let plain = ScaleRun {
        base: base.clone(),
        calibration_iters: 6,
        measure_iters: 20,
        grid: 48,
        seed: 9,
        comm_drop_deadline: None,
        jobs: 1,
    };
    let bounded = ScaleRun {
        comm_drop_deadline: Some(3.0),
        base: base.clone(),
        ..plain
    };
    let p = plain.point(24);
    let b = bounded.point(24);
    for thr in [
        p.baseline_throughput,
        p.dropcompute_throughput,
        b.baseline_throughput,
        b.dropcompute_throughput,
    ] {
        assert!(thr.is_finite() && thr > 0.0, "{thr}");
        assert!(thr <= p.linear_throughput * 1.05, "{thr}");
    }
    // both drop mechanisms must not lose much useful throughput
    assert!(p.dropcompute_throughput > 0.9 * p.baseline_throughput);
    assert!(b.baseline_throughput > 0.6 * p.baseline_throughput);
}

/// A fatally stalled worker: DropComm (bounded-wait collective) alone
/// keeps iteration time finite, the comm-side twin of the DropCompute
/// stall test above.
#[test]
fn dropcomm_survives_compute_stall() {
    use dropcompute::topology::TopologyKind;
    let cfg = dropcompute::config::ClusterConfig {
        workers: 6,
        accumulations: 4,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        stragglers: StragglerKind::Fatal { worker: 1, from_step: 2 },
        topology: Some(TopologyKind::Torus { rows: 0 }),
        link_latency: 25e-6,
        link_bandwidth: 12.5e9,
        grad_bytes: 4e6,
        comm_drop_deadline: 2.0,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, 13);
    for step in 0..5 {
        let out = sim.step(None);
        assert!(out.iter_time < 10.0, "step {step}: {}", out.iter_time);
        if step >= 2 {
            assert_eq!(out.completed[1], 0, "stalled worker excluded");
            assert_eq!(out.total_completed(), 5 * 4);
        } else {
            assert_eq!(out.total_completed(), 6 * 4);
        }
    }
}

/// The scenario-lab reference config loads, validates, and drives a
/// churned end-to-end run: the plan from `[scenario]` reaches the sim,
/// kills and revives workers on schedule, and the correlated
/// shared-burst noise stays bitwise reproducible across runs.
#[test]
fn churn_stress_config_drives_scenario_lab() {
    let doc = dropcompute::config::Document::load(std::path::Path::new(
        "configs/churn_stress.toml",
    ))
    .unwrap();
    let cfg = Config::from_doc(&doc).unwrap();
    assert!(matches!(cfg.cluster.noise, NoiseKind::SharedBurst { .. }));
    let plan = cfg.scenario.clone().expect("[scenario] spec installs");
    assert!(plan.spec().contains("rejoin+30"));
    // the sweep churn axis rides alongside: fault-free + 2 churn arms
    assert_eq!(cfg.sweep.scenarios.len(), 3);
    assert!(cfg.sweep.scenarios[0].is_empty(), "arm 0 is `none`");
    // worker 3 dies at 40 and is back at 70; worker 7 never returns
    assert!(!plan.alive(3, 50));
    assert!(plan.alive(3, 75));
    assert!(!plan.alive(7, 500));
    let mut a = ClusterSim::new(&cfg.cluster, cfg.train.seed)
        .with_fault_plan(plan.clone());
    let mut b = ClusterSim::new(&cfg.cluster, cfg.train.seed)
        .with_fault_plan(plan);
    for step in 0..130 {
        let x = a.step(None);
        let y = b.step(None);
        assert_eq!(x.iter_time.to_bits(), y.iter_time.to_bits(), "{step}");
        assert!(x.iter_time.is_finite());
        let workers = cfg.cluster.workers;
        let expect_live = match step {
            40..=69 => workers - 1,       // w3 down
            120..=129 => workers - 1,     // w7 down (w3 is back)
            _ => workers,
        };
        let live = x.completed.iter().filter(|&&d| d > 0).count();
        assert_eq!(live, expect_live, "step {step}");
    }
}
