//! Scenario-grammar fuzz suite: the `--scenario` spec language must
//! round-trip losslessly for every plan the generator can emit, and
//! reject malformed/inconsistent input with typed errors — never a
//! panic — because the same parser guards the CLI, the `[scenario]`
//! config section, the sweep axis, and v2 trace metas.

use dropcompute::rng::SplitMix64;
use dropcompute::sim::{FaultEvent, FaultPlan};
use dropcompute::util::Error;

/// A random *valid* plan: one event per chosen worker (distinct workers
/// can never overlap, so structural validation always passes).
fn random_plan(rng: &mut SplitMix64, workers: usize, horizon: u64) -> FaultPlan {
    let mut events = Vec::new();
    for worker in 0..workers {
        let step = rng.next_u64() % horizon;
        let span = 1 + rng.next_u64() % horizon;
        match rng.next_u64() % 5 {
            0 => events.push(FaultEvent::Fail { step, worker, rejoin: None }),
            1 => events.push(FaultEvent::Fail {
                step,
                worker,
                rejoin: Some(span),
            }),
            2 => events.push(FaultEvent::Slow {
                step,
                worker,
                factor: 1.0 + (rng.next_u64() % 1000) as f64 / 250.0,
                duration: (rng.next_u64() % 2 == 0).then_some(span),
            }),
            3 => events.push(FaultEvent::Drift {
                step,
                worker,
                rate: (rng.next_u64() % 1000) as f64 / 10_000.0,
            }),
            _ => {} // worker untouched by the plan
        }
    }
    FaultPlan::new(events).expect("distinct workers cannot clash")
}

#[test]
fn random_plans_round_trip_through_the_spec_grammar() {
    let mut rng = SplitMix64::new(0x5CE4_A410);
    for trial in 0..200 {
        let workers = 1 + (rng.next_u64() % 12) as usize;
        let horizon = 1 + rng.next_u64() % 500;
        let plan = random_plan(&mut rng, workers, horizon);
        let spec = plan.spec();
        let back = FaultPlan::parse(&spec)
            .unwrap_or_else(|e| panic!("trial {trial}: `{spec}`: {e}"));
        assert_eq!(back, plan, "trial {trial}: `{spec}`");
        assert_eq!(back.spec(), spec, "spec() is a fixed point");
        // semantic agreement, not just structural: alive/scale are the
        // contract the simulator consumes
        for _ in 0..32 {
            let w = (rng.next_u64() % workers as u64) as usize;
            let s = rng.next_u64() % (2 * horizon);
            assert_eq!(plan.alive(w, s), back.alive(w, s));
            assert_eq!(
                plan.scale(w, s).to_bits(),
                back.scale(w, s).to_bits()
            );
        }
    }
}

#[test]
fn seeded_plans_round_trip_too() {
    // the seeded generator promises parseable, non-overlapping output
    for seed in 0..100u64 {
        let plan = FaultPlan::seeded(seed, 16, 200);
        plan.validate().expect("seeded plans validate");
        let back = FaultPlan::parse(&plan.spec()).unwrap();
        assert_eq!(back, plan, "seed {seed}: `{}`", plan.spec());
    }
}

#[test]
fn mutated_specs_fail_typed_never_panic() {
    // chop, splice, and corrupt valid specs: every outcome must be
    // either a clean parse or an Error::Config — no panics, no
    // silently-wrong plans (anything that parses must round-trip)
    let seeds = [
        "fail@100:w3,rejoin+50",
        "kill@100:w3",
        "slow@20:w1,x2.5,for30",
        "drift@0:w2,+0.05",
        "fail@5:w0;slow@9:w4,x1.5;drift@3:w7,+0.01",
        "kill@5:w0;slow@9:w4,x1.5;kill@3:w7",
    ];
    let garbage = "@;:,wx+forrejoin0123456789garbage!";
    let mut rng = SplitMix64::new(0xBAD_5EED);
    for base in seeds {
        for trial in 0..300 {
            let mut s: Vec<char> = base.chars().collect();
            for _ in 0..=(rng.next_u64() % 3) {
                let g: Vec<char> = garbage.chars().collect();
                match rng.next_u64() % 3 {
                    0 if !s.is_empty() => {
                        // delete a char
                        let i = (rng.next_u64() as usize) % s.len();
                        s.remove(i);
                    }
                    1 if !s.is_empty() => {
                        // overwrite a char
                        let i = (rng.next_u64() as usize) % s.len();
                        s[i] = g[(rng.next_u64() as usize) % g.len()];
                    }
                    _ => {
                        // insert a char
                        let i = (rng.next_u64() as usize) % (s.len() + 1);
                        s.insert(i, g[(rng.next_u64() as usize) % g.len()]);
                    }
                }
            }
            let mutated: String = s.into_iter().collect();
            match FaultPlan::parse(&mutated) {
                Ok(plan) => {
                    // a surviving parse must still be self-consistent
                    let again = FaultPlan::parse(&plan.spec()).unwrap();
                    assert_eq!(again, plan, "trial {trial}: `{mutated}`");
                }
                Err(Error::Config(msg)) => {
                    assert!(
                        msg.contains("scenario"),
                        "trial {trial}: `{mutated}`: \
                         error should name the scenario surface: {msg}"
                    );
                }
                Err(other) => {
                    panic!("trial {trial}: `{mutated}`: wrong error {other}")
                }
            }
        }
    }
}

#[test]
fn inconsistent_plans_are_rejected_with_typed_errors() {
    // grammar-valid but semantically broken specs
    let bad = [
        // rejoin before (at) the fail: zero-length fail interval
        "fail@10:w0,rejoin+0",
        // overlapping fail intervals on one worker
        "fail@10:w0,rejoin+20;fail@15:w0,rejoin+5",
        // two unbounded fails on one worker
        "fail@10:w0;fail@50:w0",
        // overlapping slow windows on one worker
        "slow@0:w1,x2.0;slow@5:w1,x3.0",
        // duplicate drift on one worker
        "drift@0:w2,+0.01;drift@9:w2,+0.02",
        // non-positive slow factor / zero window
        "slow@0:w1,x0",
        "slow@0:w1,x-2.0",
        "slow@0:w1,x2.0,for0",
        // negative drift rate
        "drift@0:w1,+-0.5",
    ];
    for spec in bad {
        match FaultPlan::parse(spec) {
            Err(Error::Config(_)) => {}
            Ok(_) => panic!("`{spec}` must not validate"),
            Err(other) => panic!("`{spec}`: wrong error kind {other}"),
        }
    }
    // disjoint intervals on one worker are fine
    FaultPlan::parse("fail@10:w0,rejoin+5;fail@30:w0,rejoin+5").unwrap();
    FaultPlan::parse("slow@0:w1,x2.0,for5;slow@9:w1,x3.0").unwrap();
}

#[test]
fn kill_alias_fuzz_agrees_with_permanent_fail() {
    // For random (step, worker) the kill@ form must parse, agree with
    // fail@ semantically everywhere, and canonicalize to the fail form;
    // kill with any trailing argument is a typed rejection.
    let mut rng = SplitMix64::new(0x4B11_4_11A5);
    for trial in 0..200 {
        let step = rng.next_u64() % 1000;
        let worker = (rng.next_u64() % 64) as usize;
        let kill = FaultPlan::parse(&format!("kill@{step}:w{worker}"))
            .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let fail =
            FaultPlan::parse(&format!("fail@{step}:w{worker}")).unwrap();
        assert_eq!(kill, fail, "trial {trial}");
        assert_eq!(kill.spec(), format!("fail@{step}:w{worker}"));
        for _ in 0..8 {
            let s = rng.next_u64() % 2000;
            assert_eq!(kill.alive(worker, s), fail.alive(worker, s));
        }
        match FaultPlan::parse(&format!(
            "kill@{step}:w{worker},rejoin+{}",
            1 + rng.next_u64() % 100
        )) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("scenario"), "{msg}")
            }
            other => panic!("kill+rejoin must be rejected, got {other:?}"),
        }
    }
}

#[test]
fn stranded_rejoins_are_rejected_at_the_horizon_boundary() {
    // Fuzz validate_horizon: for random fail+rejoin plans, the check
    // must fire exactly when a started fail's rejoin lands at or past
    // the horizon (the previously silently-inert shape), and never for
    // permanent fails or not-yet-started events.
    let mut rng = SplitMix64::new(0x51A4_0412_0);
    for trial in 0..300 {
        let step = rng.next_u64() % 100;
        let span = 1 + rng.next_u64() % 100;
        let horizon = 1 + rng.next_u64() % 250;
        let plan =
            FaultPlan::parse(&format!("fail@{step}:w0,rejoin+{span}"))
                .unwrap();
        let stranded = step < horizon && step + span >= horizon;
        match plan.validate_horizon(horizon) {
            Ok(()) => assert!(
                !stranded,
                "trial {trial}: fail@{step},rejoin+{span} vs {horizon} \
                 should have been rejected"
            ),
            Err(Error::Config(msg)) => {
                assert!(stranded, "trial {trial}: spurious: {msg}");
                assert!(msg.contains("scenario"), "{msg}");
            }
            Err(other) => panic!("trial {trial}: wrong kind {other}"),
        }
        // permanent forms never strand
        FaultPlan::parse(&format!("kill@{step}:w0"))
            .unwrap()
            .validate_horizon(horizon)
            .unwrap();
    }
}

#[test]
fn out_of_range_worker_ids_are_a_boundary_check() {
    let plan = FaultPlan::parse("fail@0:w7").unwrap();
    // grammar-valid for any cluster...
    plan.validate().unwrap();
    // ...but a concrete 4-worker cluster rejects it at the boundary
    match plan.validate_for(4) {
        Err(Error::Config(msg)) => {
            assert!(msg.contains("w7"), "{msg}");
            assert!(msg.contains('4'), "{msg}");
        }
        other => panic!("want typed range error, got {other:?}"),
    }
    // ...while the sweep's inertness contract holds: the plan simply
    // never kills anyone who exists
    for w in 0..4 {
        for s in 0..10 {
            assert!(plan.alive(w, s));
            assert_eq!(plan.scale(w, s), 1.0);
        }
    }
    plan.validate_for(8).unwrap();
}
