//! Property tests for the unified `DropPolicy` surface: the
//! policy-driven timing paths must be bitwise equal to the legacy
//! tau/deadline code they replaced, the per-phase-deadline compiled
//! scan must be bitwise equal to its event-queue oracle, a single
//! lumped per-phase budget must be bitwise the step-level CommDeadline,
//! and the sweep's policy axis / survivor-cache pooling must reproduce
//! the legacy grids bit for bit.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::{cumulative_offsets, DropPolicy};
use dropcompute::rng::Xoshiro256pp;
use dropcompute::sim::{
    ClusterSim, CommModel, CompiledSchedule, PhaseBounded, PreemptionMode,
    ScheduleScratch,
};
use dropcompute::sweep::{SurvivorCachePool, SweepSpec};
use dropcompute::topology::TopologyKind;

/// Arrivals mixing tight clusters, moderate lateness, far stragglers
/// and negatives — the same regime grid the perf tests use.
fn random_arrivals(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n)
        .map(|_| match rng.next_below(4) {
            0 => rng.next_f64() * 0.01,
            1 => rng.next_f64() * 5.0,
            2 => 20.0 + rng.next_f64() * 50.0,
            _ => -rng.next_f64(),
        })
        .collect()
}

/// Random budget lists spanning single lumped, short, and deep shapes,
/// including zero budgets (flat cutoffs).
fn random_budgets(rng: &mut Xoshiro256pp) -> Vec<f64> {
    let len = 1 + rng.next_below(6) as usize;
    (0..len)
        .map(|_| match rng.next_below(4) {
            0 => 0.0,
            1 => rng.next_f64() * 0.5,
            2 => rng.next_f64() * 5.0,
            _ => rng.next_f64() * 40.0,
        })
        .collect()
}

#[test]
fn per_phase_compiled_scan_bitwise_equals_event_queue_oracle() {
    // the new capability's core invariant: the compiled per-phase scan
    // and the event-queue oracle agree to the bit — drop decisions,
    // survivor counts and completion times — over every topology,
    // random arrivals and random budget shapes.
    let mut rng = Xoshiro256pp::seed_from_u64(0x9A5E_D1DE);
    let mut scratch = ScheduleScratch::default();
    let mut dropped = Vec::new();
    for kind in TopologyKind::ALL {
        for n in [1usize, 2, 3, 5, 8, 12, 16, 24] {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let schedule = model.schedule_for(n).expect("topology model");
            let compiled =
                CompiledSchedule::compile(&schedule, 1e-4, 1e9, 4e6);
            for case in 0..25 {
                let arrivals = random_arrivals(n, &mut rng);
                let offsets = cumulative_offsets(&random_budgets(&mut rng));
                let (mask, want) = model.per_phase_bounded_completion(
                    &arrivals,
                    &offsets,
                    Some(&schedule),
                );
                let got = compiled.bounded_completion_with(
                    &arrivals,
                    &offsets,
                    &mut scratch,
                    &mut dropped,
                );
                let survivors = mask.iter().filter(|&&s| s).count();
                for (w, (&d, &s)) in dropped.iter().zip(&mask).enumerate() {
                    assert_eq!(
                        d, !s,
                        "{} n={n} case={case} worker {w}",
                        kind.name()
                    );
                }
                match got {
                    PhaseBounded::Complete(t) => {
                        assert_eq!(survivors, n, "{} case={case}", kind.name());
                        assert_eq!(
                            t.to_bits(),
                            want.to_bits(),
                            "{} n={n} case={case}",
                            kind.name()
                        );
                    }
                    PhaseBounded::Dropped { survivors: k, close } => {
                        assert_eq!(k, survivors);
                        // reproduce the oracle's completion from the
                        // scan's (k, close) pair exactly
                        let t = if k == 0 {
                            close.max(0.0)
                        } else {
                            model.completion_time(&vec![close; k])
                        };
                        assert_eq!(
                            t.to_bits(),
                            want.to_bits(),
                            "{} n={n} case={case} k={k}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lumped_per_phase_budget_bitwise_equals_comm_deadline() {
    // acceptance identity: PerPhaseDeadline with one lumped budget is
    // the step-level CommDeadline — end to end through ClusterSim, all
    // four topologies plus the fixed-T^c model, compiled and reference
    // arms, drop-heavy random stepping.
    let topos: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    for topo in topos {
        for reference in [false, true] {
            for deadline in [0.0, 0.8, 3.0] {
                let cfg = ClusterConfig {
                    workers: 14,
                    accumulations: 6,
                    microbatch_mean: 0.45,
                    microbatch_std: 0.02,
                    noise: NoiseKind::LogNormal { mean: 0.3, var: 0.2 },
                    stragglers: StragglerKind::Uniform {
                        p: 0.25,
                        delay: 4.0,
                    },
                    topology: topo,
                    link_latency: 1e-4,
                    link_bandwidth: 2e9,
                    grad_bytes: 1e7,
                    ..Default::default()
                };
                let build = |policy: DropPolicy| {
                    let sim = ClusterSim::new(&cfg, 0x1DEA).with_policy(policy);
                    if reference {
                        sim.with_reference_timing()
                    } else {
                        sim
                    }
                };
                let mut lumped = build(DropPolicy::per_phase_deadline(vec![
                    deadline,
                ]));
                let mut step =
                    build(DropPolicy::comm_deadline(deadline));
                for s in 0..20 {
                    let a = lumped.step(Some(6.0));
                    let b = step.step(Some(6.0));
                    assert_eq!(
                        a.completed, b.completed,
                        "{topo:?} ref={reference} d={deadline} step {s}"
                    );
                    assert_eq!(
                        a.iter_time.to_bits(),
                        b.iter_time.to_bits(),
                        "{topo:?} ref={reference} d={deadline} step {s}"
                    );
                    assert_eq!(
                        a.compute_time.to_bits(),
                        b.compute_time.to_bits()
                    );
                }
            }
        }
    }
}

#[test]
fn policy_driven_stepping_bitwise_equals_legacy_paths() {
    // every legacy (tau, preemption, deadline, H) combination expressed
    // as one DropPolicy must step bitwise identically to the legacy
    // call surface, across all four topologies.
    for kind in TopologyKind::ALL {
        let cfg = ClusterConfig {
            workers: 10,
            accumulations: 6,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.4 },
            stragglers: StragglerKind::Uniform { p: 0.2, delay: 3.0 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            comm_drop_deadline: 1.2,
            ..Default::default()
        };
        // synchronous arms: tau x preemption against step()
        for (tau, mode) in [
            (None, PreemptionMode::Preemptive),
            (Some(4.0), PreemptionMode::Preemptive),
            (Some(4.0), PreemptionMode::BetweenAccumulations),
        ] {
            let mut legacy =
                ClusterSim::new(&cfg, 0xBEA7).with_preemption(mode);
            let mut policy = DropPolicy::comm_deadline(1.2);
            if let Some(t) = tau {
                policy = policy.and(
                    DropPolicy::compute_tau(t).with_preemption(mode),
                );
            }
            let mut unified = ClusterSim::new(&cfg, 0xBEA7);
            for step in 0..12 {
                let a = legacy.step(tau);
                let b = unified.step_with(&policy);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} tau={tau:?} {mode:?} step {step}",
                    kind.name()
                );
                assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
                assert_eq!(
                    a.compute_time.to_bits(),
                    b.compute_time.to_bits()
                );
            }
        }
        // Local-SGD arm against local_sgd_period()
        let mut legacy = ClusterSim::new(&cfg, 0x10CA);
        let mut unified = ClusterSim::new(&cfg, 0x10CA);
        let policy = DropPolicy::parse("local-sgd=5+tau=0.9+deadline=1.2")
            .expect("valid spec");
        for period in 0..8 {
            let a = legacy.local_sgd_period(5, Some(0.9));
            let b = unified.step_with(&policy);
            assert_eq!(a.completed, b.completed, "{} {period}", kind.name());
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
    }
}

#[test]
fn sweep_policy_axis_and_cache_pool_reproduce_legacy_grid() {
    // the policy axis must reproduce the legacy thresholds x deadlines
    // grid bit for bit — serial, parallel, and through the pooled
    // survivor caches (memoization must be invisible).
    for kind in [TopologyKind::Ring, TopologyKind::Torus { rows: 0 }] {
        let base = ClusterConfig {
            workers: 4,
            accumulations: 5,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.5 },
            stragglers: StragglerKind::Uniform { p: 0.3, delay: 4.0 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let taus = [0.0, 2.5];
        let deadlines = [0.0, 1.0];
        let legacy = SweepSpec::new(base.clone())
            .workers(&[3, 7])
            .thresholds(&taus)
            .deadlines(&deadlines)
            .seeds(&[1, 2])
            .iters(6)
            .jobs(1)
            .run();
        let mut policies = Vec::new();
        for &tau in &taus {
            for &d in &deadlines {
                let mut p = DropPolicy::None;
                if tau > 0.0 {
                    p = p.and(DropPolicy::compute_tau(tau));
                }
                if d > 0.0 {
                    p = p.and(DropPolicy::comm_deadline(d));
                }
                policies.push(p);
            }
        }
        let spec = SweepSpec::new(base)
            .workers(&[3, 7])
            .policies(&policies)
            .seeds(&[1, 2])
            .iters(6);
        for jobs in [1usize, 3, 0] {
            let unified = spec.clone().jobs(jobs).run();
            assert_eq!(legacy.points.len(), unified.points.len());
            for (a, b) in legacy.points.iter().zip(&unified.points) {
                assert_eq!(a.index, b.index);
                assert_eq!((a.workers, a.seed), (b.workers, b.seed));
                for (x, y) in [
                    (a.mean_iter_time, b.mean_iter_time),
                    (a.mean_compute_time, b.mean_compute_time),
                    (a.throughput, b.throughput),
                    (a.drop_rate, b.drop_rate),
                ] {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} jobs={jobs} point {} ({:?})",
                        kind.name(),
                        a.index,
                        b.policy
                    );
                }
            }
        }
        // pooled vs per-point-fresh caches: identical bits
        let pool = SurvivorCachePool::new();
        for i in 0..spec.len() {
            let fresh = spec.run_point(i);
            let pooled = spec.run_point_pooled(i, &pool);
            assert_eq!(
                fresh.mean_iter_time.to_bits(),
                pooled.mean_iter_time.to_bits(),
                "{} pooled point {i}",
                kind.name()
            );
            assert_eq!(
                fresh.drop_rate.to_bits(),
                pooled.drop_rate.to_bits()
            );
        }
    }
}

#[test]
fn per_phase_policy_sweeps_and_drops_deeper_than_step_level() {
    // end-to-end through the sweep: with paired seeds the per-phase
    // arm's checkpoints subsume the step-level entry check, so it drops
    // at least as much; with tight follow-on budgets under heavy
    // stragglers it must drop strictly more somewhere.
    let base = ClusterConfig {
        workers: 12,
        accumulations: 4,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.5 },
        stragglers: StragglerKind::Uniform { p: 0.4, delay: 3.0 },
        topology: Some(TopologyKind::Ring),
        link_latency: 5e-3,
        link_bandwidth: 1e9,
        grad_bytes: 4e7,
        ..Default::default()
    };
    let r = SweepSpec::new(base)
        .workers(&[12])
        .policies(&[
            DropPolicy::comm_deadline(1.0),
            DropPolicy::per_phase_deadline(vec![1.0, 0.0, 0.0, 0.0]),
        ])
        .seeds(&[3])
        .iters(30)
        .jobs(1)
        .run();
    let (step, phase) = (&r.points[0], &r.points[1]);
    assert!(
        phase.drop_rate > step.drop_rate,
        "flat follow-on cutoffs must catch chain-stalled workers the \
         entry check admits: {} vs {}",
        phase.drop_rate,
        step.drop_rate
    );
    assert!(phase.drop_rate < 1.0, "not everyone drops");
}
