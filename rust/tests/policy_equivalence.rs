//! Property tests for the unified `DropPolicy` surface: the
//! policy-driven timing paths must be bitwise equal to the legacy
//! tau/deadline code they replaced, the per-phase-deadline compiled
//! scan must be bitwise equal to its event-queue oracle, a single
//! lumped per-phase budget must be bitwise the step-level CommDeadline,
//! and the sweep's policy axis / survivor-cache pooling must reproduce
//! the legacy grids bit for bit.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::{cumulative_offsets, DropPolicy};
use dropcompute::rng::Xoshiro256pp;
use dropcompute::sim::{
    ClusterSim, CommModel, CompiledSchedule, PhaseBounded, PreemptionMode,
    ScheduleScratch,
};
use dropcompute::sweep::{SurvivorCachePool, SweepSpec};
use dropcompute::topology::TopologyKind;

/// Arrivals mixing tight clusters, moderate lateness, far stragglers
/// and negatives — the same regime grid the perf tests use.
fn random_arrivals(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n)
        .map(|_| match rng.next_below(4) {
            0 => rng.next_f64() * 0.01,
            1 => rng.next_f64() * 5.0,
            2 => 20.0 + rng.next_f64() * 50.0,
            _ => -rng.next_f64(),
        })
        .collect()
}

/// Random budget lists spanning single lumped, short, and deep shapes,
/// including zero budgets (flat cutoffs).
fn random_budgets(rng: &mut Xoshiro256pp) -> Vec<f64> {
    let len = 1 + rng.next_below(6) as usize;
    (0..len)
        .map(|_| match rng.next_below(4) {
            0 => 0.0,
            1 => rng.next_f64() * 0.5,
            2 => rng.next_f64() * 5.0,
            _ => rng.next_f64() * 40.0,
        })
        .collect()
}

#[test]
fn per_phase_compiled_scan_bitwise_equals_event_queue_oracle() {
    // the new capability's core invariant: the compiled per-phase scan
    // and the event-queue oracle agree to the bit — drop decisions,
    // survivor counts and completion times — over every topology,
    // random arrivals and random budget shapes.
    let mut rng = Xoshiro256pp::seed_from_u64(0x9A5E_D1DE);
    let mut scratch = ScheduleScratch::default();
    let mut dropped = Vec::new();
    for kind in TopologyKind::ALL {
        for n in [1usize, 2, 3, 5, 8, 12, 16, 24] {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let schedule = model.schedule_for(n).expect("topology model");
            let compiled =
                CompiledSchedule::compile(&schedule, 1e-4, 1e9, 4e6);
            for case in 0..25 {
                let arrivals = random_arrivals(n, &mut rng);
                let offsets = cumulative_offsets(&random_budgets(&mut rng));
                let (mask, want) = model.per_phase_bounded_completion(
                    &arrivals,
                    &offsets,
                    Some(&schedule),
                );
                let got = compiled.bounded_completion_with(
                    &arrivals,
                    &offsets,
                    &mut scratch,
                    &mut dropped,
                );
                let survivors = mask.iter().filter(|&&s| s).count();
                for (w, (&d, &s)) in dropped.iter().zip(&mask).enumerate() {
                    assert_eq!(
                        d, !s,
                        "{} n={n} case={case} worker {w}",
                        kind.name()
                    );
                }
                match got {
                    PhaseBounded::Complete(t) => {
                        assert_eq!(survivors, n, "{} case={case}", kind.name());
                        assert_eq!(
                            t.to_bits(),
                            want.to_bits(),
                            "{} n={n} case={case}",
                            kind.name()
                        );
                    }
                    PhaseBounded::Dropped { survivors: k, close, checkpoint } => {
                        assert_eq!(k, survivors);
                        assert!(checkpoint < offsets.len());
                        // reproduce the oracle's completion from the
                        // scan's (k, close) pair exactly
                        let t = if k == 0 {
                            close.max(0.0)
                        } else {
                            model.completion_time(&vec![close; k])
                        };
                        assert_eq!(
                            t.to_bits(),
                            want.to_bits(),
                            "{} n={n} case={case} k={k}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lumped_per_phase_budget_bitwise_equals_comm_deadline() {
    // acceptance identity: PerPhaseDeadline with one lumped budget is
    // the step-level CommDeadline — end to end through ClusterSim, all
    // four topologies plus the fixed-T^c model, compiled and reference
    // arms, drop-heavy random stepping.
    let topos: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    for topo in topos {
        for reference in [false, true] {
            for deadline in [0.0, 0.8, 3.0] {
                let cfg = ClusterConfig {
                    workers: 14,
                    accumulations: 6,
                    microbatch_mean: 0.45,
                    microbatch_std: 0.02,
                    noise: NoiseKind::LogNormal { mean: 0.3, var: 0.2 },
                    stragglers: StragglerKind::Uniform {
                        p: 0.25,
                        delay: 4.0,
                    },
                    topology: topo,
                    link_latency: 1e-4,
                    link_bandwidth: 2e9,
                    grad_bytes: 1e7,
                    ..Default::default()
                };
                let build = |policy: DropPolicy| {
                    let sim = ClusterSim::new(&cfg, 0x1DEA).with_policy(policy);
                    if reference {
                        sim.with_reference_timing()
                    } else {
                        sim
                    }
                };
                let mut lumped = build(DropPolicy::per_phase_deadline(vec![
                    deadline,
                ]));
                let mut step =
                    build(DropPolicy::comm_deadline(deadline));
                for s in 0..20 {
                    let a = lumped.step(Some(6.0));
                    let b = step.step(Some(6.0));
                    assert_eq!(
                        a.completed, b.completed,
                        "{topo:?} ref={reference} d={deadline} step {s}"
                    );
                    assert_eq!(
                        a.iter_time.to_bits(),
                        b.iter_time.to_bits(),
                        "{topo:?} ref={reference} d={deadline} step {s}"
                    );
                    assert_eq!(
                        a.compute_time.to_bits(),
                        b.compute_time.to_bits()
                    );
                }
            }
        }
    }
}

#[test]
fn policy_driven_stepping_bitwise_equals_legacy_paths() {
    // every legacy (tau, preemption, deadline, H) combination expressed
    // as one DropPolicy must step bitwise identically to the legacy
    // call surface, across all four topologies.
    for kind in TopologyKind::ALL {
        let cfg = ClusterConfig {
            workers: 10,
            accumulations: 6,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.4 },
            stragglers: StragglerKind::Uniform { p: 0.2, delay: 3.0 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            comm_drop_deadline: 1.2,
            ..Default::default()
        };
        // synchronous arms: tau x preemption against step()
        for (tau, mode) in [
            (None, PreemptionMode::Preemptive),
            (Some(4.0), PreemptionMode::Preemptive),
            (Some(4.0), PreemptionMode::BetweenAccumulations),
        ] {
            let mut legacy =
                ClusterSim::new(&cfg, 0xBEA7).with_preemption(mode);
            let mut policy = DropPolicy::comm_deadline(1.2);
            if let Some(t) = tau {
                policy = policy.and(
                    DropPolicy::compute_tau(t).with_preemption(mode),
                );
            }
            let mut unified = ClusterSim::new(&cfg, 0xBEA7);
            for step in 0..12 {
                let a = legacy.step(tau);
                let b = unified.step_with(&policy);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} tau={tau:?} {mode:?} step {step}",
                    kind.name()
                );
                assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
                assert_eq!(
                    a.compute_time.to_bits(),
                    b.compute_time.to_bits()
                );
            }
        }
        // Local-SGD arm against local_sgd_period()
        let mut legacy = ClusterSim::new(&cfg, 0x10CA);
        let mut unified = ClusterSim::new(&cfg, 0x10CA);
        let policy = DropPolicy::parse("local-sgd=5+tau=0.9+deadline=1.2")
            .expect("valid spec");
        for period in 0..8 {
            let a = legacy.local_sgd_period(5, Some(0.9));
            let b = unified.step_with(&policy);
            assert_eq!(a.completed, b.completed, "{} {period}", kind.name());
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
    }
}

#[test]
fn sweep_policy_axis_and_cache_pool_reproduce_legacy_grid() {
    // the policy axis must reproduce the legacy thresholds x deadlines
    // grid bit for bit — serial, parallel, and through the pooled
    // survivor caches (memoization must be invisible).
    for kind in [TopologyKind::Ring, TopologyKind::Torus { rows: 0 }] {
        let base = ClusterConfig {
            workers: 4,
            accumulations: 5,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.5 },
            stragglers: StragglerKind::Uniform { p: 0.3, delay: 4.0 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let taus = [0.0, 2.5];
        let deadlines = [0.0, 1.0];
        let legacy = SweepSpec::new(base.clone())
            .workers(&[3, 7])
            .thresholds(&taus)
            .deadlines(&deadlines)
            .seeds(&[1, 2])
            .iters(6)
            .jobs(1)
            .run();
        let mut policies = Vec::new();
        for &tau in &taus {
            for &d in &deadlines {
                let mut p = DropPolicy::None;
                if tau > 0.0 {
                    p = p.and(DropPolicy::compute_tau(tau));
                }
                if d > 0.0 {
                    p = p.and(DropPolicy::comm_deadline(d));
                }
                policies.push(p);
            }
        }
        let spec = SweepSpec::new(base)
            .workers(&[3, 7])
            .policies(&policies)
            .seeds(&[1, 2])
            .iters(6);
        for jobs in [1usize, 3, 0] {
            let unified = spec.clone().jobs(jobs).run();
            assert_eq!(legacy.points.len(), unified.points.len());
            for (a, b) in legacy.points.iter().zip(&unified.points) {
                assert_eq!(a.index, b.index);
                assert_eq!((a.workers, a.seed), (b.workers, b.seed));
                for (x, y) in [
                    (a.mean_iter_time, b.mean_iter_time),
                    (a.mean_compute_time, b.mean_compute_time),
                    (a.throughput, b.throughput),
                    (a.drop_rate, b.drop_rate),
                ] {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} jobs={jobs} point {} ({:?})",
                        kind.name(),
                        a.index,
                        b.policy
                    );
                }
            }
        }
        // pooled vs per-point-fresh caches: identical bits
        let pool = SurvivorCachePool::new();
        for i in 0..spec.len() {
            let fresh = spec.run_point(i);
            let pooled = spec.run_point_pooled(i, &pool);
            assert_eq!(
                fresh.mean_iter_time.to_bits(),
                pooled.mean_iter_time.to_bits(),
                "{} pooled point {i}",
                kind.name()
            );
            assert_eq!(
                fresh.drop_rate.to_bits(),
                pooled.drop_rate.to_bits()
            );
        }
    }
}

#[test]
fn per_phase_policy_sweeps_and_drops_deeper_than_step_level() {
    // end-to-end through the sweep: with paired seeds the per-phase
    // arm's checkpoints subsume the step-level entry check, so it drops
    // at least as much; with tight follow-on budgets under heavy
    // stragglers it must drop strictly more somewhere.
    let base = ClusterConfig {
        workers: 12,
        accumulations: 4,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.5 },
        stragglers: StragglerKind::Uniform { p: 0.4, delay: 3.0 },
        topology: Some(TopologyKind::Ring),
        link_latency: 5e-3,
        link_bandwidth: 1e9,
        grad_bytes: 4e7,
        // flat zero follow-on budgets leave a restarted collective no
        // slack at all under the recursive re-check (every drop step
        // would drop everyone); this test is about the *scan*'s deep
        // checkpoints, so it pins the legacy single-restart semantics
        single_restart: true,
        ..Default::default()
    };
    let r = SweepSpec::new(base)
        .workers(&[12])
        .policies(&[
            DropPolicy::comm_deadline(1.0),
            DropPolicy::per_phase_deadline(vec![1.0, 0.0, 0.0, 0.0]),
        ])
        .seeds(&[3])
        .iters(30)
        .jobs(1)
        .run();
    let (step, phase) = (&r.points[0], &r.points[1]);
    assert!(
        phase.drop_rate > step.drop_rate,
        "flat follow-on cutoffs must catch chain-stalled workers the \
         entry check admits: {} vs {}",
        phase.drop_rate,
        step.drop_rate
    );
    assert!(phase.drop_rate < 1.0, "not everyone drops");
}

#[test]
fn recursive_restart_compiled_bitwise_equals_event_queue_oracle() {
    // the recursive restart semantics (the default since the trace PR)
    // must keep the compiled drop path and the event-queue oracle a
    // bitwise pair: random arrivals, random budget shapes, every
    // topology, end to end through ClusterSim — including cases where
    // restarts re-drop (tight follow-on budgets) and cascade several
    // levels deep.
    for kind in TopologyKind::ALL {
        for budgets in [
            vec![1.0, 0.25, 0.25],
            vec![1.0, 0.004, 0.0, 0.0], // restart misses the re-check
            vec![0.8, 0.002],
            vec![2.0, 0.001, 0.001, 0.001, 0.001],
        ] {
            let cfg = ClusterConfig {
                workers: 10,
                accumulations: 4,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                noise: NoiseKind::Exponential { mean: 0.6 },
                stragglers: StragglerKind::Uniform { p: 0.4, delay: 4.0 },
                topology: Some(kind),
                link_latency: 1e-3,
                link_bandwidth: 1e9,
                grad_bytes: 4e6,
                ..Default::default()
            };
            let policy = DropPolicy::per_phase_deadline(budgets.clone());
            let mut fast =
                ClusterSim::new(&cfg, 0x5EC5).with_policy(policy.clone());
            let mut slow = ClusterSim::new(&cfg, 0x5EC5)
                .with_reference_timing()
                .with_policy(policy);
            let mut dropped_steps = 0usize;
            for step in 0..30 {
                let a = fast.step(None);
                let b = slow.step(None);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} {budgets:?} step {step}",
                    kind.name()
                );
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} {budgets:?} step {step}",
                    kind.name()
                );
                if a.total_completed() < 10 * 4 {
                    dropped_steps += 1;
                }
            }
            assert!(
                dropped_steps > 5,
                "{} {budgets:?}: drop-heavy config ({dropped_steps}/30)",
                kind.name()
            );
        }
    }
}

#[test]
fn recursive_and_single_restart_agree_when_nothing_remains_to_recheck() {
    // a single lumped budget leaves no checkpoints after the trigger:
    // the two semantics must be bitwise identical (which also keeps the
    // lumped == step-level CommDeadline acceptance identity intact
    // under the new default).
    for kind in TopologyKind::ALL {
        let cfg = ClusterConfig {
            workers: 8,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.5 },
            stragglers: StragglerKind::Uniform { p: 0.35, delay: 4.0 },
            topology: Some(kind),
            link_latency: 1e-3,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let policy = DropPolicy::per_phase_deadline(vec![1.0]);
        let mut recursive =
            ClusterSim::new(&cfg, 0xA11).with_policy(policy.clone());
        let mut single = ClusterSim::new(&cfg, 0xA11)
            .with_single_restart()
            .with_policy(policy);
        for step in 0..20 {
            let a = recursive.step(None);
            let b = single.step(None);
            assert_eq!(a.completed, b.completed, "{} {step}", kind.name());
            assert_eq!(
                a.iter_time.to_bits(),
                b.iter_time.to_bits(),
                "{} {step}",
                kind.name()
            );
        }
    }
}

/// A random structurally-valid policy for the round-trip fuzz below.
fn random_policy(rng: &mut Xoshiro256pp) -> DropPolicy {
    let clause = |rng: &mut Xoshiro256pp| match rng.next_below(4) {
        0 => DropPolicy::compute_tau(0.1 + rng.next_f64() * 10.0)
            .with_preemption(if rng.next_below(2) == 0 {
                PreemptionMode::Preemptive
            } else {
                PreemptionMode::BetweenAccumulations
            }),
        1 => DropPolicy::comm_deadline(rng.next_f64() * 5.0),
        2 => {
            let len = 1 + rng.next_below(4) as usize;
            DropPolicy::per_phase_deadline(
                (0..len).map(|_| rng.next_f64() * 2.0).collect(),
            )
        }
        _ => DropPolicy::local_sgd(1 + rng.next_below(8) as usize),
    };
    let parts = 1 + rng.next_below(3) as usize;
    let mut p = DropPolicy::None;
    let mut have_local = false;
    for _ in 0..parts {
        let mut c = clause(rng);
        // at most one local-sgd clause is valid; resample compute taus
        while have_local && matches!(c, DropPolicy::LocalSgdPeriod { .. }) {
            c = DropPolicy::compute_tau(0.1 + rng.next_f64() * 10.0);
        }
        if matches!(c, DropPolicy::LocalSgdPeriod { .. }) {
            have_local = true;
        }
        p = p.and(c);
    }
    if p.is_none() {
        DropPolicy::None
    } else {
        p
    }
}

#[test]
fn spec_grammar_roundtrips_over_randomized_policies() {
    // parse(spec(p)) == p for randomized policies across every clause
    // kind, composition depth, and float formatting
    let mut rng = Xoshiro256pp::seed_from_u64(0x5FEC);
    for case in 0..500 {
        let p = random_policy(&mut rng);
        let spec = p.spec();
        let parsed = DropPolicy::parse(&spec)
            .unwrap_or_else(|e| panic!("case {case} `{spec}`: {e}"));
        assert_eq!(parsed, p, "case {case}: `{spec}`");
        // and the spec is a fixed point
        assert_eq!(parsed.spec(), spec, "case {case}");
    }
}

#[test]
fn spec_grammar_edge_cases_left_from_the_policy_pr() {
    // empty / whitespace-only specs
    for bad in ["", "   ", "+", "tau=1++deadline=2", " + "] {
        assert!(DropPolicy::parse(bad).is_err(), "{bad:?}");
    }
    // duplicate keys are legal composition: tightest wins
    let p = DropPolicy::parse("tau=5+tau=2+deadline=3+deadline=0.5").unwrap();
    let eff = p.effective();
    assert_eq!(eff.tau, Some(2.0));
    assert_eq!(eff.step_deadline, Some(0.5));
    // duplicate phase-deadline clauses merge elementwise-tightest
    let p = DropPolicy::parse(
        "phase-deadline=1/1+phase-deadline=0.5/2/2",
    )
    .unwrap();
    assert_eq!(p.effective().merged_phase_offsets(), vec![0.5, 2.0, 4.5]);
    // negative budgets are rejected at the grammar boundary...
    assert!(DropPolicy::parse("phase-deadline=1/-0.5").is_err());
    // ...and NaN/infinite numbers never parse into a policy
    for bad in ["tau=NaN", "deadline=inf", "phase-deadline=1/infinity"] {
        assert!(DropPolicy::parse(bad).is_err(), "{bad}");
    }
}

#[test]
fn phase_deadline_with_wrong_phase_count_vs_topology_is_well_defined() {
    // more budgets than the topology has phases: trailing checkpoints
    // apply to the final readiness (documented), never panic, and the
    // compiled/oracle pair stays bitwise — here end to end on the
    // smallest schedules, where budget lists overshoot the most
    for kind in TopologyKind::ALL {
        for workers in [1usize, 2, 3] {
            let cfg = ClusterConfig {
                workers,
                accumulations: 2,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                noise: NoiseKind::Exponential { mean: 0.4 },
                stragglers: StragglerKind::Uniform { p: 0.5, delay: 2.0 },
                topology: Some(kind),
                link_latency: 1e-3,
                link_bandwidth: 1e9,
                grad_bytes: 4e6,
                ..Default::default()
            };
            // 8 budgets >> phase count of a 1-3 worker schedule
            let policy = DropPolicy::per_phase_deadline(vec![
                1.0, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1,
            ]);
            let mut fast =
                ClusterSim::new(&cfg, 0x0DD).with_policy(policy.clone());
            let mut slow = ClusterSim::new(&cfg, 0x0DD)
                .with_reference_timing()
                .with_policy(policy);
            for step in 0..10 {
                let a = fast.step(None);
                let b = slow.step(None);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} n={workers} step {step}",
                    kind.name()
                );
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} n={workers} step {step}",
                    kind.name()
                );
                assert!(a.iter_time.is_finite());
            }
        }
    }
}

#[test]
fn fitted_budgets_step_bitwise_like_their_lumped_step_deadline() {
    // extends the lumped == step-level acceptance identity to the
    // budget fitter's output: stepping a live cluster under
    // PerPhaseDeadline([D*]) (the fitted budgets lumped into one) must
    // be bitwise CommDeadline(D*)
    let cfg = ClusterConfig {
        workers: 8,
        accumulations: 4,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.3 },
        stragglers: StragglerKind::Uniform { p: 0.25, delay: 4.0 },
        topology: Some(TopologyKind::Ring),
        link_latency: 1e-4,
        link_bandwidth: 1e9,
        grad_bytes: 4e6,
        ..Default::default()
    };
    let mut rec = ClusterSim::new(&cfg, 0xF00D);
    rec.start_recording();
    for _ in 0..20 {
        rec.step(None);
    }
    let trace = rec.finish_recording().unwrap();
    let fit = dropcompute::analysis::fit_budgets(&trace, 8, 16).unwrap();
    let deadline = fit.step_deadline.expect("tail-heavy trace fits a deadline");
    let lumped = *dropcompute::policy::cumulative_offsets(&fit.phase_budgets)
        .last()
        .expect("fitted budgets");
    assert_eq!(lumped.to_bits(), deadline.to_bits());
    let mut a = ClusterSim::new(&cfg, 0xD1E)
        .with_policy(DropPolicy::per_phase_deadline(vec![lumped]));
    let mut b = ClusterSim::new(&cfg, 0xD1E)
        .with_policy(DropPolicy::comm_deadline(deadline));
    for step in 0..20 {
        let x = a.step(None);
        let y = b.step(None);
        assert_eq!(x.completed, y.completed, "step {step}");
        assert_eq!(x.iter_time.to_bits(), y.iter_time.to_bits(), "step {step}");
    }
}
