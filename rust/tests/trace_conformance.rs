//! Trace record / replay conformance: the golden-trace harness.
//!
//! The contract under test (the trace PR's headline property): replaying
//! a recorded [`TraceRecord`] reproduces the recorded run's
//! `StepOutcome`s **bitwise**, for every topology x `DropPolicy`
//! variant, on both the compiled and the event-queue timing paths — and
//! the JSON round trip loses nothing. The checked-in fixtures under
//! `rust/tests/data/` pin those timing paths across future refactors:
//! their embedded outcomes were computed when they were minted, so any
//! drift in schedule building, the compiled pass, the bounded scans,
//! survivor restarts or the policy arithmetic fails this suite.
//!
//! On failure, CI re-runs the ignored `regen_golden_traces` test with
//! `TRACE_REGEN_DIR` set and uploads freshly-replayed fixtures as a
//! diff-friendly artifact.

use std::path::PathBuf;

use dropcompute::analysis::{evaluate_policy, fit_budgets};
use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::{cumulative_offsets, DropPolicy};
use dropcompute::sim::{ClusterSim, StepOutcome, TraceRecord};
use dropcompute::topology::TopologyKind;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data")
        .join(name)
}

const FIXTURES: [&str; 4] = [
    "ring.trace.json",
    "tree.trace.json",
    "hierarchical.trace.json",
    "torus.trace.json",
];

#[test]
fn golden_fixtures_replay_bitwise_on_both_timing_paths() {
    for name in FIXTURES {
        let trace = TraceRecord::load(&fixture_path(name))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(trace.meta.version, 1, "{name}");
        assert!(!trace.outcomes.is_empty(), "{name}: golden outcomes");
        assert_eq!(trace.outcomes.len(), trace.len(), "{name}");
        for reference in [false, true] {
            let mut sim = ClusterSim::from_trace(&trace)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            if reference {
                sim = sim.with_reference_timing();
            }
            for (i, rec) in trace.outcomes.iter().enumerate() {
                let mut out = StepOutcome::default();
                sim.replay_into(&mut out)
                    .unwrap_or_else(|e| panic!("{name} step {i}: {e}"));
                assert!(
                    rec.matches(&out),
                    "{name} step {i} (reference={reference}): replay \
                     diverged from the golden outcome\n  recorded: \
                     iter={:?} compute={:?} completed={:?}\n  replayed: \
                     iter={:?} compute={:?} completed={:?}",
                    rec.iter_time,
                    rec.compute_time,
                    rec.completed,
                    out.iter_time,
                    out.compute_time,
                    out.completed,
                );
            }
        }
        // the fixtures exercise real drop paths, not just no-ops
        let scheduled = trace.meta.workers * trace.meta.accums;
        let has_drops = trace
            .outcomes
            .iter()
            .any(|o| o.completed.iter().sum::<usize>() < scheduled);
        if name != "hierarchical.trace.json" {
            assert!(has_drops, "{name}: must pin a drop path");
        }
    }
}

#[test]
fn churn_golden_fixture_replays_bitwise_with_membership_history() {
    // the v2 golden: a hand-mintable fixed-T^c churn run whose
    // `[scenario]` meta kills worker 2 for exactly step 1. Replay must
    // reinstall the plan from the meta and reproduce the pinned
    // outcomes bitwise on both timing paths — including the faulted
    // step's compacted 2-member collective.
    let trace = TraceRecord::load(&fixture_path("churn.trace.json")).unwrap();
    assert_eq!(trace.meta.version, 2);
    assert_eq!(
        trace.meta.scenario.as_deref(),
        Some("fail@1:w2,rejoin+1")
    );
    // the JSON round trip keeps the scenario
    let reparsed = TraceRecord::parse(&trace.to_json()).unwrap();
    assert_eq!(reparsed, trace);
    for reference in [false, true] {
        let mut sim = ClusterSim::from_trace(&trace).unwrap();
        assert!(
            sim.fault_plan().is_some(),
            "from_trace must reinstall the recorded plan"
        );
        if reference {
            sim = sim.with_reference_timing();
        }
        for (i, rec) in trace.outcomes.iter().enumerate() {
            let mut out = StepOutcome::default();
            sim.replay_into(&mut out)
                .unwrap_or_else(|e| panic!("churn step {i}: {e}"));
            assert!(
                rec.matches(&out),
                "churn step {i} (reference={reference}): replay diverged\n  \
                 recorded: iter={:?} compute={:?} completed={:?}\n  \
                 replayed: iter={:?} compute={:?} completed={:?}",
                rec.iter_time,
                rec.compute_time,
                rec.completed,
                out.iter_time,
                out.compute_time,
                out.completed,
            );
        }
    }
    // the fixture pins the churn path for real: step 1 lost a worker
    assert_eq!(trace.outcomes[1].completed, vec![2, 2, 0]);
    assert_eq!(trace.outcomes[2].completed, vec![2, 2, 2], "rejoined");
}

#[test]
fn churn_record_replay_roundtrips_for_every_topology_and_policy() {
    // the scenario-lab acceptance sweep: a live run under a fault plan
    // (fail + rejoin + slow window) recorded on each topology x policy
    // replays bitwise after the JSON round trip, on both timing paths.
    let plan = dropcompute::sim::FaultPlan::parse(
        "fail@2:w1,rejoin+2;fail@5:w4;slow@0:w2,x1.5,for4",
    )
    .unwrap();
    let topologies: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    let policies =
        ["none", "tau=2.5", "deadline=1", "phase-deadline=1/0.3"];
    for &topo in &topologies {
        for spec in policies {
            let policy = DropPolicy::parse(spec).expect(spec);
            let cfg = ClusterConfig {
                workers: 6,
                accumulations: 3,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                comm_latency: 0.3,
                noise: NoiseKind::Exponential { mean: 0.4 },
                stragglers: StragglerKind::Uniform { p: 0.3, delay: 3.0 },
                topology: topo,
                link_latency: 1e-3,
                link_bandwidth: 1e9,
                grad_bytes: 4e6,
                ..Default::default()
            };
            let mut live = ClusterSim::new(&cfg, 0xC0FFEE)
                .with_policy(policy)
                .with_fault_plan(plan.clone());
            live.start_recording();
            let mut recorded = Vec::new();
            for _ in 0..8 {
                let mut out = StepOutcome::default();
                live.step_installed_into(&mut out);
                recorded.push(out);
            }
            let trace = live
                .finish_recording()
                .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
            assert_eq!(trace.meta.version, 2, "{topo:?} {spec}");
            assert_eq!(
                trace.meta.scenario.as_deref(),
                Some(plan.spec().as_str())
            );
            let parsed = TraceRecord::parse(&trace.to_json())
                .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
            assert_eq!(parsed, trace, "{topo:?} {spec}: JSON round trip");
            for reference in [false, true] {
                let mut replay = ClusterSim::from_trace(&parsed)
                    .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
                if reference {
                    replay = replay.with_reference_timing();
                }
                for (i, want) in recorded.iter().enumerate() {
                    let mut out = StepOutcome::default();
                    replay.replay_into(&mut out).unwrap_or_else(|e| {
                        panic!("{topo:?} {spec} step {i}: {e}")
                    });
                    assert!(
                        want.iter_time.to_bits()
                            == out.iter_time.to_bits()
                            && want.completed == out.completed,
                        "{topo:?} {spec} step {i} ref={reference}: churn \
                         replay diverged"
                    );
                }
            }
            // the plan actually bit: w4 is gone from step 5 on
            assert_eq!(recorded[6].completed[4], 0, "{topo:?} {spec}");
            assert_eq!(recorded[6].worker_compute[4], 0.0);
        }
    }
}

#[test]
fn record_serialize_parse_replay_roundtrips_bitwise_for_all_policies() {
    // the acceptance property: for every topology (plus the fixed-T^c
    // model) x every DropPolicy variant, a recorded seeded live run
    // replays bitwise after a full JSON round trip, on both timing
    // paths.
    let topologies: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    let policies = [
        "none",
        "tau=2.5",
        "tau=2.5,between",
        "deadline=1",
        "phase-deadline=1/0.3/0.3",
        "tau=2.5+deadline=1",
        "tau=2+phase-deadline=0.8/0.2",
        "local-sgd=4+tau=0.9",
    ];
    for &topo in &topologies {
        for spec in policies {
            let policy = DropPolicy::parse(spec).expect(spec);
            let cfg = ClusterConfig {
                workers: 6,
                accumulations: 3,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                comm_latency: 0.3,
                noise: NoiseKind::Exponential { mean: 0.4 },
                stragglers: StragglerKind::Uniform { p: 0.3, delay: 3.0 },
                topology: topo,
                link_latency: 1e-3,
                link_bandwidth: 1e9,
                grad_bytes: 4e6,
                ..Default::default()
            };
            let mut live =
                ClusterSim::new(&cfg, 0xC0FFEE).with_policy(policy);
            live.start_recording();
            let mut recorded = Vec::new();
            for _ in 0..7 {
                let mut out = StepOutcome::default();
                live.step_installed_into(&mut out);
                recorded.push(out);
            }
            let trace = live
                .finish_recording()
                .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
            // serialize -> parse must be lossless
            let parsed = TraceRecord::parse(&trace.to_json())
                .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
            assert_eq!(parsed, trace, "{topo:?} {spec}: JSON round trip");
            for reference in [false, true] {
                let mut replay = ClusterSim::from_trace(&parsed)
                    .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
                if reference {
                    replay = replay.with_reference_timing();
                }
                let outs = replay
                    .replay_all()
                    .unwrap_or_else(|e| panic!("{topo:?} {spec}: {e}"));
                assert_eq!(outs.len(), recorded.len());
                for (i, (want, got)) in
                    recorded.iter().zip(&outs).enumerate()
                {
                    assert_eq!(
                        want.iter_time.to_bits(),
                        got.iter_time.to_bits(),
                        "{topo:?} {spec} step {i} ref={reference}"
                    );
                    assert_eq!(
                        want.compute_time.to_bits(),
                        got.compute_time.to_bits(),
                        "{topo:?} {spec} step {i} ref={reference}"
                    );
                    assert_eq!(want.completed, got.completed);
                    for (a, b) in
                        want.worker_compute.iter().zip(&got.worker_compute)
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{topo:?} {spec} step {i} ref={reference}"
                        );
                    }
                }
                // the writer's embedded outcomes agree too
                for (i, rec) in parsed.outcomes.iter().enumerate() {
                    assert!(
                        rec.matches(&outs[i]),
                        "{topo:?} {spec} step {i}: embedded outcome"
                    );
                }
            }
        }
    }
}

#[test]
fn malformed_short_and_nan_traces_are_typed_errors() {
    // a missing file is an error, not a panic
    assert!(TraceRecord::load(&fixture_path("missing.trace.json")).is_err());
    let good = TraceRecord::load(&fixture_path("ring.trace.json")).unwrap();
    let text = good.to_json();
    // NaN / infinity cannot enter through JSON; both fail typed
    // (the target "2.5," is step 1's straggler delay)
    for bad in [
        text.replace("2.5,", "NaN,"),
        text.replace("2.5,", "1e999,"),
        // version 2 is readable now (scenario metas); 3 is the future
        text.replace("\"version\": 1", "\"version\": 3"),
        text.replace("\"steps\"", "\"stepz\""),
        text.replace("\"mode\": \"step\"", "\"mode\": \"period\""),
    ] {
        assert!(TraceRecord::parse(&bad).is_err());
    }
    // short trace: replaying past the end is a typed error
    let mut sim = ClusterSim::from_trace(&good).unwrap();
    sim.replay_all().unwrap();
    let mut out = StepOutcome::default();
    let err = sim.replay_into(&mut out);
    assert!(err.is_err(), "exhausted replay must be Err");
    assert!(
        format!("{}", err.unwrap_err()).contains("exhausted"),
        "error names the failure"
    );
}

#[test]
fn fit_on_golden_traces_emits_parseable_specs_near_the_grid_optimum() {
    // acceptance: `trace fit` on the golden traces produces a parseable
    // policy spec whose predicted speedup is within tolerance of an
    // independently enumerated denser grid optimum, and the fitted
    // per-phase budgets lump bitwise to the fitted step deadline.
    for name in FIXTURES {
        let trace = TraceRecord::load(&fixture_path(name)).unwrap();
        let fit = fit_budgets(&trace, 16, 32)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let parsed = DropPolicy::parse(&fit.best.spec)
            .unwrap_or_else(|e| panic!("{name}: spec `{}`: {e}", fit.best.spec));
        assert_eq!(parsed, fit.best.policy, "{name}");
        // the tree fixture was recorded under tau=1.2 — the fit must
        // flag its censored baseline; the others are uncensored
        assert_eq!(fit.censored, name == "tree.trace.json", "{name}");
        if let Some(deadline) = fit.step_deadline {
            let lump = *cumulative_offsets(&fit.phase_budgets)
                .last()
                .unwrap_or_else(|| panic!("{name}: budgets"));
            assert_eq!(
                lump.to_bits(),
                deadline.to_bits(),
                "{name}: lumped fitted budgets == step-level deadline"
            );
        }
        // denser independent grid: finer taus, every deadline boundary
        let dense = fit_budgets(&trace, 32, 4096).unwrap();
        assert!(
            fit.best.speedup >= 0.95 * dense.step_level.speedup,
            "{name}: fit {} vs dense optimum {}",
            fit.best.speedup,
            dense.step_level.speedup
        );
    }
}

#[test]
fn replay_equals_live_run_under_every_policy_through_the_sweep_axis() {
    // the sweep's replay axis re-times one recording under many
    // policies; the no-drop policy row must equal the recorded run, and
    // a tightened policy must never complete more work than recorded
    let cfg = ClusterConfig {
        workers: 5,
        accumulations: 3,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.5 },
        stragglers: StragglerKind::Uniform { p: 0.35, delay: 3.0 },
        topology: Some(TopologyKind::Tree),
        link_latency: 1e-3,
        link_bandwidth: 1e9,
        grad_bytes: 4e6,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, 0xABCD);
    sim.start_recording();
    for _ in 0..10 {
        sim.step(None);
    }
    let trace = sim.finish_recording().unwrap();
    let recorded_mean = trace.outcomes.iter().map(|o| o.iter_time).sum::<f64>()
        / trace.len() as f64;
    let policies = [
        DropPolicy::None,
        DropPolicy::comm_deadline(1.0),
        DropPolicy::parse("tau=2+phase-deadline=1/0.2").unwrap(),
    ];
    let r = dropcompute::sweep::SweepSpec::new(cfg)
        .policies(&policies)
        .seeds(&[0])
        .iters(10)
        .replay(trace.clone())
        .jobs(1)
        .run();
    assert_eq!(r.points.len(), 3);
    assert_eq!(
        r.points[0].mean_iter_time.to_bits(),
        recorded_mean.to_bits(),
        "the no-drop replay row is the recorded run"
    );
    assert_eq!(r.points[0].drop_rate, 0.0);
    for p in &r.points[1..] {
        assert!(p.drop_rate >= 0.0 && p.drop_rate < 1.0);
    }
    // direct evaluator agreement
    let (want, _) = evaluate_policy(&trace, &policies[1]).unwrap();
    assert_eq!(r.points[1].mean_iter_time.to_bits(), want.to_bits());
}

/// Regenerate the golden fixtures from the *current* code: parse each
/// fixture, replay it, and write a copy with freshly-computed outcomes
/// to `$TRACE_REGEN_DIR` — CI runs this (ignored) test when the suite
/// fails and uploads the result, so a legitimate semantic change ships
/// as a reviewable fixture diff instead of archaeology.
#[test]
#[ignore]
fn regen_golden_traces() {
    let Some(dir) = std::env::var_os("TRACE_REGEN_DIR") else {
        eprintln!("TRACE_REGEN_DIR not set; nothing to do");
        return;
    };
    let dir = PathBuf::from(dir);
    std::fs::create_dir_all(&dir).expect("create regen dir");
    for name in FIXTURES {
        let mut trace = TraceRecord::load(&fixture_path(name)).unwrap();
        let mut sim = ClusterSim::from_trace(&trace).unwrap();
        let outs = sim.replay_all().unwrap();
        trace.outcomes = outs
            .iter()
            .map(dropcompute::sim::TraceOutcome::from_outcome)
            .collect();
        trace.save(&dir.join(name)).unwrap();
        eprintln!("regenerated {}", dir.join(name).display());
    }
}
