//! Property tests for the observability layer: attaching an observer
//! must never perturb the simulation (outputs bitwise equal to the
//! observer-free paths over every topology and policy variant), the
//! recorder's typed drop attribution must cross-check against the
//! `StepOutcome` counts it observed, sweep histograms merged from
//! per-point shards must be bitwise independent of `--jobs`, and the
//! exporters must emit lint-clean Prometheus text and parseable JSON.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::obs::{
    lint_prometheus, to_json_snapshot, to_prometheus, LogHistogram,
    ObsRecorder,
};
use dropcompute::policy::DropPolicy;
use dropcompute::runtime::json::Json;
use dropcompute::sim::{ClusterSim, StepOutcome};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

/// Drop-heavy base config over `kind` (or the fixed-T^c model).
fn cfg(kind: Option<TopologyKind>, workers: usize) -> ClusterConfig {
    ClusterConfig {
        workers,
        accumulations: 4,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.5 },
        stragglers: StragglerKind::Uniform { p: 0.3, delay: 4.0 },
        topology: kind,
        link_latency: 1e-3,
        link_bandwidth: 1e9,
        grad_bytes: 4e6,
        ..Default::default()
    }
}

/// The policy variants the attribution must cover: tau-only, step
/// deadline, composed, per-phase checkpoints (which also exercises the
/// survivor-restart path), and local-SGD + tau.
fn policy_variants() -> Vec<DropPolicy> {
    vec![
        DropPolicy::compute_tau(1.2),
        DropPolicy::comm_deadline(1.0),
        DropPolicy::compute_tau(1.5).and(DropPolicy::comm_deadline(1.5)),
        DropPolicy::per_phase_deadline(vec![1.0, 0.3, 0.3]),
        DropPolicy::parse("local-sgd=5+tau=0.9").expect("valid spec"),
    ]
}

#[test]
fn observer_attached_stepping_is_bitwise_observer_free() {
    // the zero-overhead contract's correctness half: a live ObsRecorder
    // must not perturb a single bit of any outcome — every topology
    // plus fixed-T^c, every policy variant, compiled and event-queue
    // oracle arms.
    let topos: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    for topo in topos {
        for policy in policy_variants() {
            for reference in [false, true] {
                let build = || {
                    let sim = ClusterSim::new(&cfg(topo, 10), 0x0B5E)
                        .with_policy(policy.clone());
                    if reference {
                        sim.with_reference_timing()
                    } else {
                        sim
                    }
                };
                let mut plain = build();
                let mut observed = build();
                let mut out_a = StepOutcome::default();
                let mut out_b = StepOutcome::default();
                let mut rec = ObsRecorder::new(10);
                for step in 0..15 {
                    plain.step_installed_into(&mut out_a);
                    observed.step_installed_observed(&mut out_b, &mut rec);
                    assert_eq!(
                        out_a.completed, out_b.completed,
                        "{topo:?} {} ref={reference} step {step}",
                        policy.spec()
                    );
                    assert_eq!(
                        out_a.iter_time.to_bits(),
                        out_b.iter_time.to_bits(),
                        "{topo:?} {} ref={reference} step {step}",
                        policy.spec()
                    );
                    assert_eq!(
                        out_a.compute_time.to_bits(),
                        out_b.compute_time.to_bits()
                    );
                    assert_eq!(out_a.worker_compute, out_b.worker_compute);
                }
            }
        }
    }
}

#[test]
fn drop_attribution_cross_checks_against_step_outcomes() {
    // the recorder's typed totals must reconcile exactly with what the
    // StepOutcomes say happened: micro-batch balance, completed totals,
    // comm-excluded worker-steps (zeroed completions that comm caused),
    // and per-worker step participation.
    let topos: Vec<Option<TopologyKind>> = std::iter::once(None)
        .chain(TopologyKind::ALL.iter().copied().map(Some))
        .collect();
    for topo in topos {
        for policy in policy_variants() {
            let n = 10usize;
            let mut sim = ClusterSim::new(&cfg(topo, n), 0xCC0)
                .with_policy(policy.clone());
            let mut rec = ObsRecorder::new(n);
            let mut out = StepOutcome::default();
            let steps = 25usize;
            let mut completed_total = 0u64;
            let per_step = policy.local_sgd_h().unwrap_or(4);
            for _ in 0..steps {
                sim.step_installed_observed(&mut out, &mut rec);
                completed_total += out.total_completed() as u64;
            }
            let label =
                format!("{topo:?} {}", policy.spec());
            assert_eq!(rec.steps, steps as u64, "{label}");
            assert_eq!(
                rec.completed_microbatches, completed_total,
                "{label}"
            );
            // every worker scheduled per_step micro-batches every step
            assert_eq!(
                rec.scheduled_microbatches,
                (steps * n * per_step) as u64,
                "{label}"
            );
            assert!(rec.microbatches_balance(), "{label}");
            assert_eq!(rec.workers.len(), n, "{label}");
            for (w, s) in rec.workers.iter().enumerate() {
                assert_eq!(s.steps, steps as u64, "{label} worker {w}");
                assert!(
                    s.dropped <= steps as u64,
                    "{label} worker {w}"
                );
            }
            // the drop-heavy configs must actually exercise the cause
            // this policy variant is about
            let eff = policy.effective();
            if eff.tau.is_some() {
                assert!(rec.drops.tau_events > 0, "{label}");
            }
            // a preemptive tau clause flattens every trimmed arrival to
            // exactly tau, so a composed deadline may legitimately never
            // fire — only the deadline-only variant must show exclusions
            if eff.step_deadline.is_some() && eff.tau.is_none() {
                assert!(rec.drops.step_deadline > 0, "{label}");
            }
            if !eff.phase_offsets.is_empty() {
                assert!(rec.drops.phase_checkpoint > 0, "{label}");
                assert_eq!(rec.drops.step_deadline, 0, "{label}");
            }
            // comm exclusions are exactly one event per excluded
            // worker-step, and each zeroed a positive completion count
            // or the worker had already finished nothing
            assert_eq!(
                rec.drops.comm_events(),
                rec.workers.iter().map(|s| s.dropped).sum::<u64>(),
                "{label}"
            );
            // was_max is awarded exactly once per step
            assert_eq!(
                rec.workers.iter().map(|s| s.was_max).sum::<u64>(),
                steps as u64,
                "{label}"
            );
            // triggered-checkpoint only on steps with comm exclusions
            assert!(
                rec.workers
                    .iter()
                    .map(|s| s.triggered_checkpoint)
                    .sum::<u64>()
                    <= steps as u64,
                "{label}"
            );
        }
    }
}

#[test]
fn sweep_merged_histograms_are_bitwise_independent_of_jobs() {
    // the mergeability contract end to end: per-point recorders from a
    // parallel sweep fold into a merged recorder bitwise identical to
    // the serial run's — sums, percentiles, attribution tables.
    let spec = SweepSpec::new(cfg(Some(TopologyKind::Ring), 6))
        .workers(&[4, 6])
        .policies(&policy_variants())
        .seeds(&[1, 2])
        .iters(8)
        .progress(false);
    let (r1, o1) = spec.clone().jobs(1).run_observed();
    let (r4, o4) = spec.clone().jobs(4).run_observed();
    for (a, b) in r1.points.iter().zip(&r4.points) {
        assert_eq!(a.mean_iter_time.to_bits(), b.mean_iter_time.to_bits());
    }
    assert_eq!(o1.per_point.len(), o4.per_point.len());
    for (i, (a, b)) in o1.per_point.iter().zip(&o4.per_point).enumerate() {
        assert_eq!(a.steps, b.steps, "point {i}");
        assert_eq!(
            a.iter_time.sum().to_bits(),
            b.iter_time.sum().to_bits(),
            "point {i}"
        );
        assert_eq!(a.drops, b.drops, "point {i}");
    }
    let (a, b) = (&o1.merged, &o4.merged);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.workers, b.workers);
    assert_eq!(a.drops, b.drops);
    for (ha, hb) in [
        (&a.iter_time, &b.iter_time),
        (&a.compute_time, &b.compute_time),
        (&a.arrival_offset, &b.arrival_offset),
    ] {
        assert_eq!(ha.count(), hb.count());
        assert_eq!(ha.sum().to_bits(), hb.sum().to_bits());
        assert_eq!(ha.min().to_bits(), hb.min().to_bits());
        assert_eq!(ha.max().to_bits(), hb.max().to_bits());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(
                ha.percentile(q).to_bits(),
                hb.percentile(q).to_bits(),
                "q={q}"
            );
        }
    }
    assert!(a.microbatches_balance());
    // and the merged recorder saw every point's steps
    assert_eq!(a.steps, (spec.len() * 8) as u64);
}

#[test]
fn histogram_percentile_edge_cases() {
    // empty histogram: every readout is NaN, count 0
    let h = LogHistogram::new();
    assert_eq!(h.count(), 0);
    assert!(h.percentile(0.5).is_nan());
    assert!(h.mean().is_nan());
    assert!(h.min().is_nan());

    // single sample: every percentile is exactly that sample
    let mut h = LogHistogram::new();
    h.record(0.3721);
    for q in [0.0, 0.5, 0.99, 1.0] {
        assert_eq!(h.percentile(q).to_bits(), 0.3721f64.to_bits(), "q={q}");
    }

    // non-finite and negative samples are rejected, not recorded
    let mut h = LogHistogram::new();
    h.record(f64::NAN);
    h.record(f64::INFINITY);
    h.record(f64::NEG_INFINITY);
    h.record(-1.0);
    assert_eq!(h.count(), 0);
    assert_eq!(h.rejected(), 4);
    assert!(h.percentile(0.5).is_nan());

    // zero is a valid sample (bucket 0: the p0 readout is bucket 0's
    // upper edge), and the saturating top bucket clamps to the exact
    // observed max instead of reporting infinity
    let mut h = LogHistogram::new();
    h.record(0.0);
    h.record(1e300);
    assert_eq!(h.count(), 2);
    assert_eq!(h.min().to_bits(), 0.0f64.to_bits());
    assert!(h.percentile(0.0) <= dropcompute::obs::hist::LO);
    assert_eq!(h.percentile(1.0).to_bits(), 1e300f64.to_bits());
    assert!(h.percentile(1.0).is_finite());
}

#[test]
fn exports_from_a_real_run_lint_and_parse() {
    // a drop-heavy observed run's Prometheus text must pass the
    // in-tree exposition linter, and the JSON snapshot must round-trip
    // through the crate's own parser with consistent totals.
    let mut sim = ClusterSim::new(&cfg(Some(TopologyKind::Torus { rows: 0 }), 8), 0xE59)
        .with_policy(
            DropPolicy::compute_tau(1.5).and(DropPolicy::comm_deadline(1.2)),
        );
    let mut rec = ObsRecorder::new(8);
    let mut out = StepOutcome::default();
    for _ in 0..30 {
        sim.step_installed_observed(&mut out, &mut rec);
    }
    let prom = to_prometheus(&rec);
    let issues = lint_prometheus(&prom);
    assert!(issues.is_empty(), "lint issues: {issues:?}");
    assert!(prom.contains("dropcompute_steps_total 30"));

    let snap = to_json_snapshot(&rec);
    let doc = Json::parse(&snap).expect("snapshot parses");
    assert_eq!(
        doc.get("steps").and_then(Json::as_f64),
        Some(30.0)
    );
    let workers = doc.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 8);
    let hist = doc.get("iter_time").expect("iter_time histogram");
    assert_eq!(hist.get("count").and_then(Json::as_f64), Some(30.0));
}
