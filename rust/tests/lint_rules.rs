//! Lint-engine rule tests: one seeded bad fixture per rule asserting
//! the exact diagnostic (rule key, severity, line, message), clean
//! fixtures per rule exercising the deliberate non-flags (allowlisted
//! paths, guarded wildcards, pattern-only enum detection, inner-block
//! guards, test-code exemption), suppression and baseline round-trips,
//! and a self-lint of this very repo under deny semantics.

use std::path::Path;

use dropcompute::lint::{
    self, apply_baseline, known_rule, lint_source, rule_info, Baseline,
    Diagnostic, LintReport, Severity, Suppressed, ENUM_WILDCARD,
    HOTPATH_ALLOC, HOTPATH_PANIC, LINT_USAGE, LOCK_ACROSS_IO, RULES,
    UNORDERED_ITER, WALL_CLOCK,
};

fn active(diags: &[Diagnostic]) -> Vec<&Diagnostic> {
    diags.iter().filter(|d| d.is_active()).collect()
}

// ---------------------------------------------------------------- rule 1

#[test]
fn wall_clock_flagged_outside_allowlist() {
    let src = r#"
pub fn step() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
"#;
    let diags = lint_source("sim/clock_use.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 1);
    let d = act[0];
    assert_eq!(d.rule, WALL_CLOCK);
    assert_eq!(d.severity, Severity::Deny);
    assert_eq!(d.line, 3);
    assert!(d.message.contains("virtual clock"), "{}", d.message);
    assert_eq!(d.snippet, "let t0 = std::time::Instant::now();");

    // SystemTime is flagged by bare name (no call path required)
    let sys = "pub fn t() -> std::time::SystemTime { todo!() }\n";
    let diags = lint_source("analysis/x.rs", sys);
    assert_eq!(active(&diags).len(), 1);
    assert_eq!(active(&diags)[0].rule, WALL_CLOCK);
}

#[test]
fn wall_clock_clean_shapes() {
    let src = "pub fn t() -> f64 { Instant::now().elapsed().as_secs_f64() }\n";
    // the real transport, the sanctioned timer, and the sweep progress
    // meter read wall clocks by design
    for path in ["transport/peer.rs", "util/stopwatch.rs", "sweep/runner.rs"] {
        assert!(active(&lint_source(path, src)).is_empty(), "{path}");
    }
    // test code anywhere may read clocks freely
    let test_src = r#"
#[cfg(test)]
mod tests {
    #[test]
    fn timer() {
        let _ = std::time::Instant::now();
    }
}
"#;
    assert!(active(&lint_source("sim/x.rs", test_src)).is_empty());
    // prose in comments never trips the rule
    let comment = "// Instant::now() would be wrong here\npub fn f() {}\n";
    assert!(active(&lint_source("sim/x.rs", comment)).is_empty());
}

// ---------------------------------------------------------------- rule 2

#[test]
fn unordered_iter_flagged_in_ordered_modules() {
    let src = "use std::collections::HashMap;\npub struct S { m: HashMap<u32, u32> }\n";
    let diags = lint_source("sweep/cache.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 2);
    assert!(act.iter().all(|d| d.rule == UNORDERED_ITER));
    assert_eq!(act[0].line, 1);
    assert!(act[0].message.contains("BTreeMap"), "{}", act[0].message);

    let set = "use std::collections::HashSet;\n";
    assert_eq!(active(&lint_source("obs/x.rs", set)).len(), 1);

    // outside the determinism-critical modules the same source is fine
    assert!(active(&lint_source("report/table.rs", src)).is_empty());
    assert!(active(&lint_source("config/mod.rs", src)).is_empty());
}

// ---------------------------------------------------------------- rule 3

#[test]
fn enum_wildcard_flagged_on_closed_enum() {
    let src = r#"
fn f(p: &DropPolicy) -> bool {
    match p {
        DropPolicy::None => true,
        _ => false,
    }
}
"#;
    let diags = lint_source("policy/mod.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 1);
    let d = act[0];
    assert_eq!(d.rule, ENUM_WILDCARD);
    assert_eq!(d.severity, Severity::Deny);
    assert_eq!(d.line, 5);
    assert!(d.message.contains("DropPolicy"), "{}", d.message);
    assert!(d.message.contains("future variant"), "{}", d.message);
}

#[test]
fn enum_wildcard_clean_shapes() {
    // a guarded wildcard is a deliberate predicate catch-all
    let guarded = r#"
fn f(p: &DropPolicy, c: bool) -> bool {
    match p {
        DropPolicy::None => true,
        _ if c => true,
        DropPolicy::ComputeTau { .. } | DropPolicy::Composed(_) => false,
    }
}
"#;
    assert!(active(&lint_source("policy/mod.rs", guarded)).is_empty());

    // constructors in arm *bodies* do not make this a match on the
    // enum — only patterns count
    let len_match = r#"
fn g(parts: &[u32]) -> DropPolicy {
    match parts.len() {
        0 => DropPolicy::None,
        _ => DropPolicy::Composed(Vec::new()),
    }
}
"#;
    assert!(active(&lint_source("policy/mod.rs", len_match)).is_empty());

    // tuple patterns with per-element wildcards are fine — only an arm
    // whose entire pattern is `_` swallows variants
    let tuple = r#"
fn t(a: &FaultEvent, b: &FaultEvent) -> bool {
    match (a, b) {
        (FaultEvent::Fail { .. }, FaultEvent::Fail { .. }) => true,
        (FaultEvent::Fail { .. }, _)
        | (FaultEvent::Slow { .. }, _)
        | (FaultEvent::Drift { .. }, _) => false,
    }
}
"#;
    assert!(active(&lint_source("sim/fault.rs", tuple)).is_empty());

    // open (non-catalog) enums may wildcard at will
    let open_enum = r#"
fn h(e: std::io::ErrorKind) -> bool {
    match e {
        std::io::ErrorKind::BrokenPipe => true,
        _ => false,
    }
}
"#;
    assert!(active(&lint_source("util/mod.rs", open_enum)).is_empty());
}

// ---------------------------------------------------------------- rule 4

#[test]
fn hotpath_panic_flagged_only_in_designated_fn() {
    let src = r#"
impl C {
    pub fn step_into(&mut self) -> f64 {
        self.slot.as_ref().unwrap().value()
    }
    pub fn warmup(&mut self) -> f64 {
        self.slot.as_ref().expect("warmed").value()
    }
}
"#;
    let diags = lint_source("sim/cluster.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 1, "only the designated fn is flagged");
    let d = act[0];
    assert_eq!(d.rule, HOTPATH_PANIC);
    assert_eq!(d.line, 4);
    assert!(d.message.contains("step_into"), "{}", d.message);
    assert!(d.message.contains("unwrap"), "{}", d.message);

    // the designation is (file, fn): the same source elsewhere is clean
    assert!(active(&lint_source("sim/other.rs", src)).is_empty());
}

// ---------------------------------------------------------------- rule 5

#[test]
fn hotpath_alloc_flags_every_form() {
    let src = r#"
impl S {
    pub fn bounded_completion(&mut self) -> usize {
        let v = vec![1u32];
        let w: Vec<u32> = v.iter().copied().collect();
        let b = Box::new(w.len());
        *b
    }
    pub fn ensure_slot(&mut self) {
        self.scratch = Vec::new();
    }
}
"#;
    let diags = lint_source("sim/survivor.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 3, "vec![], collect(), Box::new — warmup exempt");
    assert!(act.iter().all(|d| d.rule == HOTPATH_ALLOC));
    assert_eq!(act[0].line, 4);
    assert!(act[0].message.contains("vec![]"), "{}", act[0].message);
    assert!(act[1].message.contains("collect()"), "{}", act[1].message);
    assert!(act[2].message.contains("Box::new"), "{}", act[2].message);
    assert!(act.iter().all(|d| d.message.contains("bounded_completion")));
}

// ------------------------------------------- rules 4+5: batched stepping

#[test]
fn batch_lockstep_fns_are_designated_hot() {
    // The PR-10 SoA stepping path is designated hot exactly like the
    // scalar oracle it mirrors: `step_installed_into`, `lockstep_pass`
    // and `scan_max4` in sim/batch.rs must stay panic- and
    // allocation-free in steady state.
    let bad = r#"
impl ReplicaBatch {
    pub fn step_installed_into(&mut self, outs: &mut [StepOutcome]) {
        self.lanes.first().expect("a lane");
    }
    fn lockstep_pass(&mut self) {
        let gathered: Vec<f64> = self.ready.iter().copied().collect();
        self.lane_buf = gathered;
    }
}
pub fn scan_max4(xs: &[f64]) -> f64 {
    xs.iter().cloned().reduce(f64::max).unwrap()
}
"#;
    let diags = lint_source("sim/batch.rs", bad);
    let act = active(&diags);
    assert_eq!(act.len(), 3, "expect, collect, unwrap");
    assert_eq!(act[0].rule, HOTPATH_PANIC);
    assert_eq!(act[0].line, 4);
    assert!(
        act[0].message.contains("step_installed_into"),
        "{}",
        act[0].message
    );
    assert_eq!(act[1].rule, HOTPATH_ALLOC);
    assert_eq!(act[1].line, 7);
    assert!(act[1].message.contains("lockstep_pass"), "{}", act[1].message);
    assert_eq!(act[2].rule, HOTPATH_PANIC);
    assert_eq!(act[2].line, 12);
    assert!(act[2].message.contains("scan_max4"), "{}", act[2].message);

    // the designation is (file, fn): the same source elsewhere is clean
    assert!(active(&lint_source("sim/batch_scratch.rs", bad)).is_empty());

    // the real steady-state idiom is clean — scratch reuse via clear /
    // resize / push into pre-grown buffers, shape asserts allowed, and
    // warmup/convenience fns (`step_installed`, `from_sims`) may
    // allocate freely
    let clean = r#"
impl ReplicaBatch {
    pub fn step_installed_into(&mut self, outs: &mut [StepOutcome]) {
        assert_eq!(outs.len(), self.sims.len());
        self.lanes.clear();
        self.lanes.push(0);
    }
    fn lockstep_pass(&mut self) {
        self.ready.resize(8, 0.0);
        self.next.copy_from_slice(&self.ready);
        std::mem::swap(&mut self.ready, &mut self.next);
    }
    pub fn step_installed(&mut self) -> Vec<StepOutcome> {
        let mut outs = vec![StepOutcome::default(); self.sims.len()];
        self.step_installed_into(&mut outs);
        outs
    }
}
pub fn scan_max4(xs: &[f64]) -> f64 {
    let mut m = f64::NEG_INFINITY;
    for &x in xs {
        m = m.max(x);
    }
    m
}
"#;
    assert!(active(&lint_source("sim/batch.rs", clean)).is_empty());
}

// ---------------------------------------------------------------- rule 6

#[test]
fn lock_across_io_flagged() {
    let src = r#"
fn send(&self) {
    let mut conn = self.slot.lock().unwrap();
    write_frame(&mut conn);
}
"#;
    let diags = lint_source("transport/x.rs", src);
    let act = active(&diags);
    assert_eq!(act.len(), 1);
    let d = act[0];
    assert_eq!(d.rule, LOCK_ACROSS_IO);
    assert_eq!(d.severity, Severity::Deny);
    assert_eq!(d.line, 3, "diagnostic points at the guard's `let`");
    assert!(d.message.contains("conn"), "{}", d.message);
    assert!(d.message.contains("write_frame"), "{}", d.message);
}

#[test]
fn lock_across_io_clean_shapes() {
    // an explicit drop releases the guard before the blocking call
    let dropped = r#"
fn send(&self) {
    let mut conn = self.slot.lock().unwrap();
    conn.push(1);
    drop(conn);
    write_frame();
}
"#;
    assert!(active(&lint_source("transport/x.rs", dropped)).is_empty());

    // a guard confined to an initializer block dies at the `}` and
    // never taints the outer binding
    let inner = r#"
fn send(&self) {
    let d = {
        let mut rng = self.rng.lock().unwrap();
        rng.next()
    };
    sleep(d);
}
"#;
    assert!(active(&lint_source("transport/x.rs", inner)).is_empty());

    // outside transport/ and collective/ the rule does not apply
    let outside = r#"
fn send(&self) {
    let mut conn = self.slot.lock().unwrap();
    write_frame(&mut conn);
}
"#;
    assert!(active(&lint_source("sweep/pool.rs", outside)).is_empty());
}

// ----------------------------------------------------------- suppression

#[test]
fn inline_allow_suppresses_same_line_and_line_above() {
    let same_line = "fn f() -> f64 { now_secs(Instant::now()) } // lint:allow(wall-clock): report timer\n";
    let diags = lint_source("sim/x.rs", same_line);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].suppressed, Some(Suppressed::Inline));
    assert!(active(&diags).is_empty());

    let line_above = r#"
fn f() {
    // lint:allow(wall-clock): host timer for a human-facing report
    let _ = std::time::Instant::now();
}
"#;
    let diags = lint_source("sim/x.rs", line_above);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].suppressed, Some(Suppressed::Inline));

    // an allow only covers its own rule
    let wrong_rule = r#"
fn f() {
    // lint:allow(unordered-iter): misdirected
    let _ = std::time::Instant::now();
}
"#;
    assert_eq!(active(&lint_source("sim/x.rs", wrong_rule)).len(), 1);
}

#[test]
fn unknown_allow_rule_is_a_warn_finding() {
    let src = "fn f() {} // lint:allow(no-such-rule)\n";
    let diags = lint_source("sim/x.rs", src);
    assert_eq!(diags.len(), 1);
    let d = &diags[0];
    assert_eq!(d.rule, LINT_USAGE);
    assert_eq!(d.severity, Severity::Warn);
    assert!(d.is_active(), "misuse of the surface is never self-excused");
    assert!(d.message.contains("no-such-rule"), "{}", d.message);
    assert!(d.message.contains("wall-clock"), "lists known rules: {}", d.message);
}

// -------------------------------------------------------------- baseline

#[test]
fn baseline_round_trip_suppresses_then_resurfaces() {
    let src = "\
use std::collections::HashMap;
pub type M = HashMap<u32, u32>;
";
    let mut diags = lint_source("sim/map.rs", src);
    assert_eq!(active(&diags).len(), 2);

    // format → parse → apply: every finding suppressed, nothing stale
    let text = Baseline::format(diags.iter());
    let mut bl = Baseline::parse(&text);
    assert_eq!(bl.len(), 2);
    apply_baseline(&mut diags, &mut bl);
    assert!(active(&diags).is_empty());
    assert!(diags
        .iter()
        .all(|d| d.suppressed == Some(Suppressed::Baseline)));
    assert!(bl.stale().is_empty());

    // touching the flagged line changes its content address: the
    // finding resurfaces and the orphaned entry reports stale
    let edited = "\
use std::collections::HashMap as Map;
pub type M = HashMap<u32, u32>;
";
    let mut diags2 = lint_source("sim/map.rs", edited);
    let mut bl2 = Baseline::parse(&text);
    apply_baseline(&mut diags2, &mut bl2);
    assert_eq!(active(&diags2).len(), 1);
    assert_eq!(bl2.stale().len(), 1);
}

// ----------------------------------------------------- catalog + report

#[test]
fn rule_catalog_is_deny_and_known() {
    assert_eq!(RULES.len(), 6);
    for r in &RULES {
        assert_eq!(r.severity, Severity::Deny, "{}", r.key);
        assert!(known_rule(r.key));
        assert!(rule_info(r.key).is_some());
        assert!(!r.name.is_empty() && !r.summary.is_empty());
    }
    assert!(known_rule(LINT_USAGE), "meta rule is a legal allow target");
    assert!(rule_info(LINT_USAGE).is_none(), "but has no catalog entry");
    assert!(!known_rule("no-such-rule"));
}

#[test]
fn report_json_escapes_and_summarizes() {
    let report = LintReport {
        root: "rust/src".into(),
        files_scanned: 1,
        diagnostics: vec![Diagnostic {
            rule: WALL_CLOCK,
            severity: Severity::Deny,
            file: "sim/x.rs".into(),
            line: 3,
            message: "uses \"quotes\"".into(),
            snippet: "let t = Instant::now();".into(),
            suppressed: None,
        }],
    };
    let json = report.to_json();
    assert!(json.contains("\"files_scanned\": 1"), "{json}");
    assert!(json.contains("\\\"quotes\\\""), "{json}");
    assert!(json.contains("\"deny\": 1"), "{json}");
    assert!(json.contains("\"suppressed\": null"), "{json}");
}

// -------------------------------------------------------------- self-lint

/// The deny gate on this very repo: the tree lints clean against the
/// checked-in (empty) baseline, with every deliberate exception
/// inline-allowed at its site. This is exactly what the CI `lint-gate`
/// job runs via `dropcompute lint --deny`.
#[test]
fn repo_self_lints_clean_under_deny() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.join("rust/src");
    let baseline =
        Baseline::load(&manifest.join("lint-baseline.txt")).unwrap();
    let report = lint::lint_root(&root, baseline).unwrap();
    assert!(report.files_scanned > 50, "walked {}", report.files_scanned);

    let act: Vec<String> = report
        .active()
        .map(|d| format!("{} {}:{} {}", d.rule, d.file, d.line, d.message))
        .collect();
    assert!(act.is_empty(), "self-lint found findings:\n{}", act.join("\n"));
    assert_eq!(report.active_deny(), 0);
    assert_eq!(report.active_warn(), 0, "no unknown allows, no stale baseline");

    // the deliberate exceptions live inline next to their code: the 3
    // CLI bench timers, the 4 ensure_slot expects, the send-path guard
    assert!(
        report.suppressed(Suppressed::Inline) >= 8,
        "expected the known inline allows, got {}",
        report.suppressed(Suppressed::Inline)
    );
    assert_eq!(report.suppressed(Suppressed::Baseline), 0);
}
