//! Property tests for the perf core: the compiled schedule fast path
//! must be bitwise equal to the event-queue reference oracle on every
//! topology, and the parallel sweep engine must be bitwise equal to a
//! serial run — the two invariants that make "fast" safe to trust.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::coordinator::ScaleRun;
use dropcompute::rng::Xoshiro256pp;
use dropcompute::sim::{schedule_completion, ClusterSim, CompiledSchedule, ScheduleScratch};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

/// Random-but-reproducible link parameters spanning latency-bound to
/// bandwidth-bound regimes.
fn random_link(rng: &mut Xoshiro256pp) -> (f64, f64, f64) {
    let latency = 10f64.powf(-6.0 + 4.0 * rng.next_f64()); // 1us .. 10ms
    let bandwidth = 10f64.powf(8.0 + 2.5 * rng.next_f64()); // 0.1 .. 30 GB/s
    let bytes = 10f64.powf(3.0 + 6.0 * rng.next_f64()); // 1KB .. 1GB
    (latency, bandwidth, bytes)
}

#[test]
fn compiled_schedule_bitwise_equals_event_queue_for_all_topologies() {
    let mut rng = Xoshiro256pp::seed_from_u64(0xC0_D1F1ED);
    let mut scratch = ScheduleScratch::default();
    for kind in TopologyKind::ALL {
        for n in [1usize, 2, 3, 4, 5, 7, 8, 12, 16, 23, 32] {
            let schedule = kind.build(n);
            for _case in 0..6 {
                let (latency, bandwidth, bytes) = random_link(&mut rng);
                // arrivals mixing tight clusters, stragglers, negatives
                let arrivals: Vec<f64> = (0..n)
                    .map(|_| match rng.next_below(4) {
                        0 => rng.next_f64() * 0.01,
                        1 => rng.next_f64() * 10.0,
                        2 => 50.0 + rng.next_f64() * 100.0,
                        _ => -rng.next_f64(),
                    })
                    .collect();
                let want = schedule_completion(
                    &schedule, &arrivals, latency, bandwidth, bytes,
                );
                let compiled = CompiledSchedule::compile(
                    &schedule, latency, bandwidth, bytes,
                );
                let got = compiled.completion_with(&arrivals, &mut scratch);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} n={n}: compiled {got} vs reference {want}",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn cluster_sim_compiled_equals_reference_under_noise_and_drops() {
    // End-to-end: full ClusterSim stepping (noise, stragglers,
    // DropCompute threshold, DropComm deadline) with the compiled fast
    // path vs the event-queue oracle, bit for bit.
    for kind in TopologyKind::ALL {
        for (deadline, tau) in [(0.0, None), (2.0, Some(6.5)), (1.0, None)] {
            let cfg = ClusterConfig {
                workers: 24,
                accumulations: 8,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                noise: NoiseKind::LogNormal { mean: 0.3, var: 0.2 },
                stragglers: StragglerKind::Uniform { p: 0.1, delay: 4.0 },
                topology: Some(kind),
                link_latency: 1e-4,
                link_bandwidth: 2e9,
                grad_bytes: 1e7,
                comm_drop_deadline: deadline,
                ..Default::default()
            };
            let mut fast = ClusterSim::new(&cfg, 0xAB);
            let mut slow = ClusterSim::new(&cfg, 0xAB).with_reference_timing();
            for step in 0..25 {
                let a = fast.step(tau);
                let b = slow.step(tau);
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} deadline={deadline} step={step}",
                    kind.name()
                );
                assert_eq!(a.completed, b.completed);
                assert_eq!(a.compute_time.to_bits(), b.compute_time.to_bits());
            }
        }
    }
}

#[test]
fn parallel_sweep_bitwise_equals_serial_run() {
    for kind in TopologyKind::ALL {
        let base = ClusterConfig {
            workers: 4,
            accumulations: 6,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.3 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let spec = SweepSpec::new(base)
            .workers(&[2, 5, 9])
            .thresholds(&[0.0, 3.0])
            .deadlines(&[0.0, 1.5])
            .seeds(&[11, 12])
            .iters(8);
        let serial = spec.clone().jobs(1).run();
        for jobs in [2usize, 4, 0] {
            let parallel = spec.clone().jobs(jobs).run();
            assert_eq!(serial.points.len(), parallel.points.len());
            for (a, b) in serial.points.iter().zip(&parallel.points) {
                assert_eq!(a.index, b.index);
                assert_eq!(
                    (a.workers, a.seed),
                    (b.workers, b.seed),
                    "{} jobs={jobs}",
                    kind.name()
                );
                for (x, y) in [
                    (a.mean_iter_time, b.mean_iter_time),
                    (a.mean_compute_time, b.mean_compute_time),
                    (a.throughput, b.throughput),
                    (a.drop_rate, b.drop_rate),
                    (a.threshold, b.threshold),
                    (a.deadline, b.deadline),
                ] {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} jobs={jobs} point {}",
                        kind.name(),
                        a.index
                    );
                }
            }
        }
    }
}

#[test]
fn scale_run_parallel_sweep_equals_serial() {
    let base = ClusterConfig {
        workers: 1,
        accumulations: 6,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.3,
        noise: NoiseKind::Gamma { mean: 0.2, var: 0.05 },
        ..Default::default()
    };
    let mut run = ScaleRun {
        base,
        calibration_iters: 4,
        measure_iters: 8,
        grid: 24,
        seed: 77,
        ..ScaleRun::default()
    };
    let ns = [2usize, 3, 5, 8];
    let serial = run.sweep(&ns);
    run.jobs = 4;
    let parallel = run.sweep(&ns);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.workers, b.workers);
        assert_eq!(
            a.baseline_throughput.to_bits(),
            b.baseline_throughput.to_bits()
        );
        assert_eq!(
            a.dropcompute_throughput.to_bits(),
            b.dropcompute_throughput.to_bits()
        );
        assert_eq!(a.tau.to_bits(), b.tau.to_bits());
    }
}
