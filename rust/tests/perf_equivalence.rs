//! Property tests for the perf core: the compiled schedule fast path
//! must be bitwise equal to the event-queue reference oracle on every
//! topology, the cached survivor collective must be bitwise equal to
//! the event-queue `bounded_wait_completion`, the enum noise sampler
//! must be draw-for-draw identical to the boxed one (and the batched
//! fills stream-identical to sequential draws), and the parallel sweep
//! engine must be bitwise equal to a serial run — the invariants that
//! make "fast" safe to trust.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::coordinator::ScaleRun;
use dropcompute::rng::{Distribution, Xoshiro256pp};
use dropcompute::sim::{
    bounded_wait_cutoff, build_noise, schedule_completion, ClusterSim,
    CommModel, CompiledSchedule, LatencyModel, NoiseSampler, PreemptionMode,
    ScheduleScratch, SurvivorScheduleCache,
};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

/// Random-but-reproducible link parameters spanning latency-bound to
/// bandwidth-bound regimes.
fn random_link(rng: &mut Xoshiro256pp) -> (f64, f64, f64) {
    let latency = 10f64.powf(-6.0 + 4.0 * rng.next_f64()); // 1us .. 10ms
    let bandwidth = 10f64.powf(8.0 + 2.5 * rng.next_f64()); // 0.1 .. 30 GB/s
    let bytes = 10f64.powf(3.0 + 6.0 * rng.next_f64()); // 1KB .. 1GB
    (latency, bandwidth, bytes)
}

#[test]
fn compiled_schedule_bitwise_equals_event_queue_for_all_topologies() {
    let mut rng = Xoshiro256pp::seed_from_u64(0xC0_D1F1ED);
    let mut scratch = ScheduleScratch::default();
    for kind in TopologyKind::ALL {
        for n in [1usize, 2, 3, 4, 5, 7, 8, 12, 16, 23, 32] {
            let schedule = kind.build(n);
            for _case in 0..6 {
                let (latency, bandwidth, bytes) = random_link(&mut rng);
                // arrivals mixing tight clusters, stragglers, negatives
                let arrivals: Vec<f64> = (0..n)
                    .map(|_| match rng.next_below(4) {
                        0 => rng.next_f64() * 0.01,
                        1 => rng.next_f64() * 10.0,
                        2 => 50.0 + rng.next_f64() * 100.0,
                        _ => -rng.next_f64(),
                    })
                    .collect();
                let want = schedule_completion(
                    &schedule, &arrivals, latency, bandwidth, bytes,
                );
                let compiled = CompiledSchedule::compile(
                    &schedule, latency, bandwidth, bytes,
                );
                let got = compiled.completion_with(&arrivals, &mut scratch);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} n={n}: compiled {got} vs reference {want}",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn cluster_sim_compiled_equals_reference_under_noise_and_drops() {
    // End-to-end: full ClusterSim stepping (noise, stragglers,
    // DropCompute threshold, DropComm deadline) with the compiled fast
    // path vs the event-queue oracle, bit for bit.
    for kind in TopologyKind::ALL {
        for (deadline, tau) in [(0.0, None), (2.0, Some(6.5)), (1.0, None)] {
            let cfg = ClusterConfig {
                workers: 24,
                accumulations: 8,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                noise: NoiseKind::LogNormal { mean: 0.3, var: 0.2 },
                stragglers: StragglerKind::Uniform { p: 0.1, delay: 4.0 },
                topology: Some(kind),
                link_latency: 1e-4,
                link_bandwidth: 2e9,
                grad_bytes: 1e7,
                comm_drop_deadline: deadline,
                ..Default::default()
            };
            let mut fast = ClusterSim::new(&cfg, 0xAB);
            let mut slow = ClusterSim::new(&cfg, 0xAB).with_reference_timing();
            for step in 0..25 {
                let a = fast.step(tau);
                let b = slow.step(tau);
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} deadline={deadline} step={step}",
                    kind.name()
                );
                assert_eq!(a.completed, b.completed);
                assert_eq!(a.compute_time.to_bits(), b.compute_time.to_bits());
            }
        }
    }
}

#[test]
fn survivor_cache_bitwise_equals_bounded_wait_oracle() {
    // The drop-path invariant: for every topology (and the fixed-T^c
    // model), random arrivals and random deadlines — including 0 (only
    // ties with the first arrival survive, everyone else dropped) and
    // deadlines loose enough that nobody drops — the cached k-survivor
    // collective must be bitwise equal to the event-queue
    // bounded_wait_completion, while compiling each k at most once.
    let mut rng = Xoshiro256pp::seed_from_u64(0x50F7_51DE);
    let models: Vec<CommModel> = TopologyKind::ALL
        .iter()
        .map(|&kind| CommModel::Topology {
            kind,
            latency: 1e-4,
            bandwidth: 1e9,
            bytes: 4e6,
        })
        .chain(std::iter::once(CommModel::Fixed(0.35)))
        .collect();
    for model in &models {
        for n in [1usize, 2, 3, 5, 8, 12, 16, 24] {
            let mut cache = SurvivorScheduleCache::new(model);
            let mut seen_ks = std::collections::HashSet::new();
            for case in 0..40 {
                let arrivals: Vec<f64> = (0..n)
                    .map(|_| match rng.next_below(4) {
                        0 => rng.next_f64() * 0.01,
                        1 => rng.next_f64() * 5.0,
                        2 => 20.0 + rng.next_f64() * 50.0,
                        _ => -rng.next_f64(),
                    })
                    .collect();
                let deadline = match case % 5 {
                    0 => 0.0,
                    1 => -1.0, // clamps to 0 like the membership rule
                    2 => 1e9,  // nobody excluded
                    3 => rng.next_f64() * 0.5,
                    _ => rng.next_f64() * 30.0,
                };
                let (mask, want) =
                    model.bounded_wait_completion(&arrivals, deadline);
                let k = mask.iter().filter(|&&s| s).count();
                if k == arrivals.len() {
                    // no exclusion: the full-N compiled path covers this
                    // (tested above); the cache only serves drop steps
                    continue;
                }
                seen_ks.insert(k);
                let close = bounded_wait_cutoff(&arrivals, deadline);
                let got = cache.completion(k, close);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{model:?} n={n} k={k} deadline={deadline}: \
                     cached {got} vs oracle {want}"
                );
            }
            let is_fixed = matches!(model, CommModel::Fixed(_));
            let want_compiles = if is_fixed { 0 } else { seen_ks.len() };
            assert_eq!(
                cache.compiled_count(),
                want_compiles,
                "{model:?} n={n}: one compile per survivor count"
            );
        }
    }
}

#[test]
fn enum_noise_sampler_matches_boxed_draw_for_draw() {
    // Every NoiseKind family: the closed enum sampler must consume the
    // stream identically to the boxed trait object — per draw, and
    // through the batched fill.
    let kinds = [
        NoiseKind::None,
        NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        },
        NoiseKind::LogNormal { mean: 0.225, var: 0.05 },
        NoiseKind::Normal { mean: 0.225, var: 0.05 },
        NoiseKind::Bernoulli { p: 0.5, value: 0.45 },
        NoiseKind::Exponential { mean: 0.225 },
        NoiseKind::Gamma { mean: 0.225, var: 0.05 },
        // alpha < 1 exercises Marsaglia-Tsang's boost branch
        NoiseKind::Gamma { mean: 0.1, var: 0.05 },
    ];
    for kind in &kinds {
        let sampler = NoiseSampler::from_kind(kind);
        let Some(boxed) = build_noise(kind) else {
            assert!(sampler.is_none(), "{kind:?}");
            continue;
        };
        let mut r_boxed = Xoshiro256pp::seed_from_u64(0xBEEF);
        let mut r_enum = Xoshiro256pp::seed_from_u64(0xBEEF);
        for i in 0..20_000 {
            assert_eq!(
                boxed.sample(&mut r_boxed).to_bits(),
                sampler.sample(&mut r_enum).to_bits(),
                "{kind:?} draw {i}"
            );
        }
        // batched fill: same values, same end-of-stream position
        let mut buf = vec![0.0f64; 3_000];
        sampler.fill(&mut buf, &mut r_enum);
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(
                x.to_bits(),
                boxed.sample(&mut r_boxed).to_bits(),
                "{kind:?} fill draw {i}"
            );
        }
        assert_eq!(r_boxed.next_u64(), r_enum.next_u64(), "{kind:?}");
        assert_eq!(boxed.mean().to_bits(), sampler.mean().to_bits());
        assert_eq!(boxed.variance().to_bits(), sampler.variance().to_bits());
    }
}

/// The pre-batching sequential step algorithm, reconstructed from
/// public APIs: per worker, straggler draw then one
/// `sample_microbatch` per accumulation, stopping at the first
/// threshold crossing. The batched `ClusterSim::step` must match it
/// bitwise — including each worker's stream position, which is what the
/// multi-step loop checks.
fn reference_step(
    model: &LatencyModel,
    streams: &mut [Xoshiro256pp],
    accums: usize,
    threshold: Option<f64>,
    mode: PreemptionMode,
    step_idx: usize,
) -> (Vec<f64>, Vec<usize>) {
    let mut worker_compute = Vec::with_capacity(streams.len());
    let mut completed = Vec::with_capacity(streams.len());
    for (n, rng) in streams.iter_mut().enumerate() {
        let mut t = model.sample_straggler_at(n, step_idx, rng);
        let mut done = 0usize;
        match (threshold, mode) {
            (None, _) => {
                for _ in 0..accums {
                    t += model.sample_microbatch(n, rng);
                }
                done = accums;
            }
            (Some(tau), PreemptionMode::Preemptive) => {
                for _ in 0..accums {
                    let next = t + model.sample_microbatch(n, rng);
                    if next < tau {
                        t = next;
                        done += 1;
                    } else {
                        break;
                    }
                }
                if done < accums {
                    t = tau;
                }
            }
            (Some(tau), PreemptionMode::BetweenAccumulations) => {
                for _ in 0..accums {
                    t += model.sample_microbatch(n, rng);
                    done += 1;
                    if t >= tau {
                        break;
                    }
                }
            }
        }
        worker_compute.push(t);
        completed.push(done);
    }
    (worker_compute, completed)
}

#[test]
fn batched_step_bitwise_matches_sequential_reference() {
    // Batched fills must not move any worker's stream position: every
    // noise family x straggler scenario x preemption mode x threshold,
    // over enough consecutive steps that one extra/missing draw
    // anywhere would cascade into a mismatch.
    let noises = [
        NoiseKind::None,
        NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        },
        NoiseKind::Normal { mean: 0.225, var: 0.05 },
        NoiseKind::Gamma { mean: 0.225, var: 0.05 },
    ];
    let stragglers = [
        StragglerKind::None,
        StragglerKind::Uniform { p: 0.3, delay: 2.0 },
        StragglerKind::SingleServer { p: 0.5, delay: 2.0, server_size: 3 },
        StragglerKind::Fatal { worker: 2, from_step: 4 },
    ];
    for noise in &noises {
        for strag in &stragglers {
            for (threshold, mode) in [
                (None, PreemptionMode::Preemptive),
                (Some(6.0), PreemptionMode::Preemptive),
                (Some(6.0), PreemptionMode::BetweenAccumulations),
                (Some(2.0), PreemptionMode::Preemptive),
            ] {
                let cfg = ClusterConfig {
                    workers: 6,
                    accumulations: 8,
                    microbatch_mean: 0.45,
                    microbatch_std: 0.02,
                    comm_latency: 0.3,
                    noise: noise.clone(),
                    stragglers: strag.clone(),
                    ..Default::default()
                };
                let seed = 0xA11CE;
                let mut sim = ClusterSim::new(&cfg, seed).with_preemption(mode);
                // mirror ClusterSim's stream construction
                let root = Xoshiro256pp::seed_from_u64(seed);
                let mut streams: Vec<Xoshiro256pp> =
                    (0..cfg.workers).map(|n| root.split(n as u64)).collect();
                let model = LatencyModel::from_config(&cfg);
                for step in 0..12 {
                    let out = sim.step(threshold);
                    let (wc, done) = reference_step(
                        &model,
                        &mut streams,
                        cfg.accumulations,
                        threshold,
                        mode,
                        step,
                    );
                    assert_eq!(
                        out.completed, done,
                        "{noise:?} {strag:?} {threshold:?} {mode:?} step {step}"
                    );
                    for (w, (a, b)) in
                        out.worker_compute.iter().zip(&wc).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{noise:?} {strag:?} {threshold:?} {mode:?} \
                             step {step} worker {w}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn batched_local_sgd_bitwise_matches_sequential_reference() {
    // The worker-major, batched local_sgd_period against the original
    // local-major sequential loop: per-worker streams see the same
    // draw order either way (each worker owns its stream), so results
    // must be bitwise identical across straggler kinds that do and
    // don't consume randomness.
    let stragglers = [
        StragglerKind::None,
        StragglerKind::Uniform { p: 0.25, delay: 1.0 },
        StragglerKind::SingleServer { p: 0.6, delay: 1.5, server_size: 2 },
        StragglerKind::Fatal { worker: 1, from_step: 1 },
    ];
    for strag in &stragglers {
        for threshold in [None, Some(0.9)] {
            let cfg = ClusterConfig {
                workers: 5,
                accumulations: 1,
                microbatch_mean: 0.45,
                microbatch_std: 0.02,
                comm_latency: 0.2,
                noise: NoiseKind::Exponential { mean: 0.15 },
                stragglers: strag.clone(),
                ..Default::default()
            };
            let seed = 0x10CA1;
            let h = 7;
            let mut sim = ClusterSim::new(&cfg, seed);
            let root = Xoshiro256pp::seed_from_u64(seed);
            let mut streams: Vec<Xoshiro256pp> =
                (0..cfg.workers).map(|n| root.split(n as u64)).collect();
            let model = LatencyModel::from_config(&cfg);
            for period in 0..5usize {
                let step_idx = period;
                let out = sim.local_sgd_period(h, threshold);
                // the original algorithm: local-step-major loops
                let mut wc = vec![0.0f64; cfg.workers];
                let mut done = vec![0usize; cfg.workers];
                for _local in 0..h {
                    for n in 0..cfg.workers {
                        let rng = &mut streams[n];
                        let mut t =
                            model.sample_straggler_at(n, step_idx, rng);
                        t += model.sample_microbatch(n, rng);
                        match threshold {
                            Some(tau) => {
                                if t < tau {
                                    done[n] += 1;
                                    wc[n] += t;
                                } else {
                                    wc[n] += tau;
                                }
                            }
                            None => {
                                done[n] += 1;
                                wc[n] += t;
                            }
                        }
                    }
                }
                assert_eq!(
                    out.completed, done,
                    "{strag:?} {threshold:?} period {period}"
                );
                for (w, (a, b)) in out.worker_compute.iter().zip(&wc).enumerate()
                {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{strag:?} {threshold:?} period {period} worker {w}"
                    );
                }
            }
        }
    }
}

#[test]
fn parallel_sweep_bitwise_equals_serial_run() {
    for kind in TopologyKind::ALL {
        let base = ClusterConfig {
            workers: 4,
            accumulations: 6,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.3 },
            topology: Some(kind),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let spec = SweepSpec::new(base)
            .workers(&[2, 5, 9])
            .thresholds(&[0.0, 3.0])
            .deadlines(&[0.0, 1.5])
            .seeds(&[11, 12])
            .iters(8);
        let serial = spec.clone().jobs(1).run();
        for jobs in [2usize, 4, 0] {
            let parallel = spec.clone().jobs(jobs).run();
            assert_eq!(serial.points.len(), parallel.points.len());
            for (a, b) in serial.points.iter().zip(&parallel.points) {
                assert_eq!(a.index, b.index);
                assert_eq!(
                    (a.workers, a.seed),
                    (b.workers, b.seed),
                    "{} jobs={jobs}",
                    kind.name()
                );
                for (x, y) in [
                    (a.mean_iter_time, b.mean_iter_time),
                    (a.mean_compute_time, b.mean_compute_time),
                    (a.throughput, b.throughput),
                    (a.drop_rate, b.drop_rate),
                    (a.threshold, b.threshold),
                    (a.deadline, b.deadline),
                ] {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} jobs={jobs} point {}",
                        kind.name(),
                        a.index
                    );
                }
            }
        }
    }
}

#[test]
fn scale_run_parallel_sweep_equals_serial() {
    let base = ClusterConfig {
        workers: 1,
        accumulations: 6,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.3,
        noise: NoiseKind::Gamma { mean: 0.2, var: 0.05 },
        ..Default::default()
    };
    let mut run = ScaleRun {
        base,
        calibration_iters: 4,
        measure_iters: 8,
        grid: 24,
        seed: 77,
        ..ScaleRun::default()
    };
    let ns = [2usize, 3, 5, 8];
    let serial = run.sweep(&ns);
    run.jobs = 4;
    let parallel = run.sweep(&ns);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.workers, b.workers);
        assert_eq!(
            a.baseline_throughput.to_bits(),
            b.baseline_throughput.to_bits()
        );
        assert_eq!(
            a.dropcompute_throughput.to_bits(),
            b.dropcompute_throughput.to_bits()
        );
        assert_eq!(a.tau.to_bits(), b.tau.to_bits());
    }
}
