//! Sim-to-real parity: the socket transport executes the *same*
//! [`topology::Schedule`] plans as the in-process mpsc mesh, with the
//! same fixed application order — so on integer-valued gradients
//! (where float addition is exact under any association) every rank's
//! result must be bitwise identical across the socket path, the mpsc
//! path, and a straight summation oracle, for every topology and both
//! element widths. Plus the degradation path: a survivor subset with a
//! peer dead before phase 0, and a full loopback kill run through both
//! acceptance gates.
//!
//! [`topology::Schedule`]: dropcompute::topology::Schedule

use std::ops::AddAssign;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dropcompute::collective::{topology_all_reduce, MeshComm};
use dropcompute::obs::ObsRecorder;
use dropcompute::policy::DropPolicy;
use dropcompute::sim::FaultPlan;
use dropcompute::topology::TopologyKind;
use dropcompute::transport::{
    bind_mesh, replay_bitwise, run_loopback, subgroup_all_reduce,
    transport_all_reduce, RetryPolicy, RunSpec, SocketMesh, TransportKind,
    Wire,
};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "dropcompute-parity-{}-{}-{}",
        std::process::id(),
        tag,
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Integer-valued per-rank gradient: exact under float addition in any
/// order, so cross-path comparisons can demand bitwise equality.
fn init<T: Wire>(rank: usize, len: usize) -> Vec<T> {
    (0..len)
        .map(|i| T::from_f64(((rank + 1) * (i % 7 + 3)) as f64))
        .collect()
}

/// Every rank's buffer after a full socket all-reduce over `topo`.
fn socket_all_reduce<T: Wire + AddAssign>(
    transport: TransportKind,
    topo: TopologyKind,
    n: usize,
    len: usize,
) -> Vec<Vec<T>> {
    let dir = scratch_dir(topo.name());
    let (bindings, endpoints) = bind_mesh(transport, n, &dir).unwrap();
    let eps = Arc::new(endpoints);
    let mut handles = Vec::new();
    for binding in bindings {
        let eps = Arc::clone(&eps);
        handles.push(std::thread::spawn(move || {
            let rank = binding.rank;
            let mesh = SocketMesh::<T>::establish(
                binding,
                &eps,
                RetryPolicy::default(),
                Duration::from_secs(20),
            )
            .unwrap();
            let mut buf = init::<T>(rank, len);
            transport_all_reduce(
                &mesh,
                topo,
                0,
                &mut buf,
                Duration::from_secs(20),
            )
            .unwrap();
            buf
        }));
    }
    let out: Vec<Vec<T>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// The same collective over the in-process mpsc mesh.
fn mpsc_all_reduce<T: Wire + AddAssign>(
    topo: TopologyKind,
    n: usize,
    len: usize,
) -> Vec<Vec<T>> {
    let handles: Vec<_> = MeshComm::<T>::full(n)
        .into_iter()
        .map(|comm| {
            std::thread::spawn(move || {
                let mut buf = init::<T>(comm.rank, len);
                topology_all_reduce(&comm, topo, &mut buf);
                buf
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn assert_parity<T: Wire + AddAssign>(
    transport: TransportKind,
    topo: TopologyKind,
    n: usize,
    len: usize,
) {
    let socket = socket_all_reduce::<T>(transport, topo, n, len);
    let mpsc = mpsc_all_reduce::<T>(topo, n, len);
    // straight-summation oracle: exact for integer-valued inputs
    let oracle: Vec<f64> = (0..len)
        .map(|i| {
            (0..n).map(|r| init::<T>(r, len)[i].to_f64()).sum::<f64>()
        })
        .collect();
    for rank in 0..n {
        for i in 0..len {
            assert_eq!(
                socket[rank][i].to_f64().to_bits(),
                mpsc[rank][i].to_f64().to_bits(),
                "{transport}/{} rank {rank} elem {i}: socket vs mpsc",
                topo.name()
            );
            assert_eq!(
                socket[rank][i].to_f64(),
                oracle[i],
                "{transport}/{} rank {rank} elem {i}: socket vs oracle",
                topo.name()
            );
        }
    }
}

#[test]
fn uds_matches_mpsc_and_oracle_on_every_topology() {
    // odd length exercises the chunk-remainder paths
    for topo in TopologyKind::ALL {
        assert_parity::<f32>(TransportKind::Uds, topo, 4, 97);
        assert_parity::<f64>(TransportKind::Uds, topo, 4, 97);
    }
}

#[test]
fn tcp_matches_mpsc_and_oracle() {
    assert_parity::<f32>(TransportKind::Tcp, TopologyKind::Ring, 4, 97);
    assert_parity::<f64>(TransportKind::Tcp, TopologyKind::Tree, 4, 33);
}

/// The degradation path: rank 2 connects, then dies before phase 0.
/// The survivors reduce as a 3-member subgroup and must match a
/// 3-rank mpsc mesh carrying the same (global-rank-valued) gradients.
#[test]
fn survivor_subset_matches_reduced_mpsc_mesh() {
    let n = 4;
    let len = 61;
    let members: Vec<usize> = vec![0, 1, 3];
    let topo = TopologyKind::Ring;

    let dir = scratch_dir("subset");
    let (bindings, endpoints) = bind_mesh(TransportKind::Uds, n, &dir).unwrap();
    let eps = Arc::new(endpoints);
    let mut handles = Vec::new();
    for binding in bindings {
        let eps = Arc::clone(&eps);
        let members = members.clone();
        handles.push(std::thread::spawn(move || {
            let rank = binding.rank;
            let mesh = SocketMesh::<f32>::establish(
                binding,
                &eps,
                RetryPolicy::default(),
                Duration::from_secs(20),
            )
            .unwrap();
            if rank == 2 {
                // die before phase 0: drop the mesh, sockets close,
                // survivors never hear from us
                return None;
            }
            let schedule = topo.build(members.len());
            let mut buf = init::<f32>(rank, len);
            subgroup_all_reduce(
                &mesh,
                &members,
                &schedule,
                0,
                &mut buf,
                Duration::from_secs(20),
            )
            .unwrap();
            Some(buf)
        }));
    }
    let socket: Vec<Option<Vec<f32>>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    std::fs::remove_dir_all(&dir).ok();
    assert!(socket[2].is_none());

    // reference: a k=3 mpsc mesh where mesh rank j carries global rank
    // members[j]'s gradient
    let members_ref = members.clone();
    let handles: Vec<_> = MeshComm::<f32>::full(members.len())
        .into_iter()
        .map(|comm| {
            let members = members_ref.clone();
            std::thread::spawn(move || {
                let mut buf = init::<f32>(members[comm.rank], len);
                topology_all_reduce(&comm, topo, &mut buf);
                buf
            })
        })
        .collect();
    let reference: Vec<Vec<f32>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (j, &rank) in members.iter().enumerate() {
        let got = socket[rank].as_ref().unwrap();
        for i in 0..len {
            assert_eq!(
                got[i].to_bits(),
                reference[j][i].to_bits(),
                "member {rank} elem {i}"
            );
        }
    }
}

/// End-to-end: a loopback run with a mid-run kill completes (nobody
/// hangs on the dead peer), its trace replays bitwise through the
/// simulator on both timing paths, the conformance gate passes, and
/// the obs recorder books the fault exactly once per dead step.
#[test]
fn loopback_kill_run_survives_and_replays_bitwise() {
    let spec = RunSpec {
        workers: 4,
        accums: 2,
        iters: 4,
        kind: TransportKind::Uds,
        topo: TopologyKind::Ring,
        policy: DropPolicy::parse("deadline=0.25").unwrap(),
        plan: Some(FaultPlan::parse("kill@1:w3").unwrap()),
        retry: RetryPolicy::default(),
        recv_deadline: Duration::from_secs(5),
        compute_ms: 2.0,
        skew_ms: 10.0,
        // the deterministic rank skew here is 2·10 = 20ms per adjacent
        // pair; a 0.1s gate means no ordering pair is scored, keeping
        // the test robust on loaded CI machines (membership is still
        // checked exactly)
        min_gap: 0.1,
        grad_len: 64,
        seed: 0xD50C,
        dir: None,
        latency: 25e-6,
        bandwidth: 12.5e9,
        bytes: 64.0 * 4.0,
    };
    let mut rec = ObsRecorder::new(spec.workers);
    let report = run_loopback(&spec, Some(&mut rec)).unwrap();

    assert_eq!(report.steps.len(), 4);
    // steps 1..4: worker 3 is plan-dead and out of the membership
    for (s, step) in report.steps.iter().enumerate() {
        if s == 0 {
            assert_eq!(step.plan_alive, vec![0, 1, 2, 3]);
        } else {
            assert_eq!(step.plan_alive, vec![0, 1, 2]);
            assert!(!step.members.contains(&3));
        }
    }
    // trace: v2, transport meta present, replays bitwise on both paths
    let trace = &report.trace;
    assert!(trace.meta.transport.is_some());
    // kill@ is sugar; spec() renders the canonical rejoin-less fail
    assert_eq!(trace.meta.scenario.as_deref(), Some("fail@1:w3"));
    let reparsed =
        dropcompute::sim::TraceRecord::parse(&trace.to_json()).unwrap();
    assert_eq!(reparsed.meta.transport, trace.meta.transport);
    assert_eq!(replay_bitwise(trace).unwrap(), 4);
    assert!(
        report.conformance.passed(),
        "conformance: {}",
        report.conformance
    );
    // obs: one worker_fault per dead step, transport stats populated
    assert_eq!(rec.drops.worker_fault, 3);
    assert!(rec.transport.used());
    assert!(rec.transport.frames_sent > 0);
}
