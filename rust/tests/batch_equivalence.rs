//! Property tests for multi-replica batched stepping: a
//! [`ReplicaBatch`] SoA lockstep step must be bitwise identical to
//! stepping each replica alone through the scalar pass — across every
//! topology, every `DropPolicy` variant, every batch width (including
//! ragged tails), drop-heavy DropComm regimes, churned fault plans and
//! replay-sourced timing — and a sweep's results must be bitwise
//! independent of both `--batch` and `--jobs`. The batched RNG fills
//! must leave every replica's per-worker streams exactly where solo
//! stepping leaves them, including the bounded fill's early stop and
//! the end-of-stream state.

use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::DropPolicy;
use dropcompute::rng::Xoshiro256pp;
use dropcompute::sim::{
    scan_max4, ClusterSim, FaultPlan, LatencyModel, PreemptionMode,
    ReplicaBatch, StepOutcome,
};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

fn cfg(kind: TopologyKind, workers: usize) -> ClusterConfig {
    ClusterConfig {
        workers,
        accumulations: 5,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.35 },
        stragglers: StragglerKind::Uniform { p: 0.3, delay: 3.0 },
        topology: Some(kind),
        link_latency: 1e-4,
        link_bandwidth: 1e9,
        grad_bytes: 4e6,
        ..Default::default()
    }
}

/// Every policy shape the drop surface can express: none, tau under
/// both preemption modes, step deadline, per-phase checkpoints,
/// Local-SGD, and a composition.
fn policy_variants() -> Vec<DropPolicy> {
    vec![
        DropPolicy::None,
        DropPolicy::compute_tau(4.0),
        DropPolicy::compute_tau(4.0)
            .with_preemption(PreemptionMode::BetweenAccumulations),
        DropPolicy::comm_deadline(1.0),
        DropPolicy::per_phase_deadline(vec![1.0, 0.3, 0.3]),
        DropPolicy::local_sgd(4),
        DropPolicy::parse("tau=4+deadline=1.2").expect("valid spec"),
    ]
}

fn assert_outcomes_eq(a: &StepOutcome, b: &StepOutcome, what: &str) {
    assert_eq!(
        a.iter_time.to_bits(),
        b.iter_time.to_bits(),
        "{what}: iter_time {} vs {}",
        a.iter_time,
        b.iter_time
    );
    assert_eq!(
        a.compute_time.to_bits(),
        b.compute_time.to_bits(),
        "{what}: compute_time"
    );
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(
        a.worker_compute.len(),
        b.worker_compute.len(),
        "{what}: worker_compute len"
    );
    for (w, (x, y)) in
        a.worker_compute.iter().zip(&b.worker_compute).enumerate()
    {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: worker_compute[{w}]"
        );
    }
}

#[test]
fn batched_stepping_bitwise_equals_solo_across_everything() {
    // the tentpole invariant: all 4 topologies x every DropPolicy
    // variant x batch widths 1, 2, S and S+ragged — every lane of the
    // SoA pass carries the bits its solo scalar run would
    for kind in TopologyKind::ALL {
        for (pi, policy) in policy_variants().iter().enumerate() {
            for width in [1usize, 2, 4, 7] {
                let cfg = cfg(kind, 8);
                let seeds: Vec<u64> =
                    (0..width as u64).map(|r| 0xBA5E + 13 * r).collect();
                let mut batch = ReplicaBatch::new(&cfg, policy, &seeds);
                let mut solos: Vec<ClusterSim> = seeds
                    .iter()
                    .map(|&s| {
                        ClusterSim::new(&cfg, s)
                            .with_policy(policy.clone())
                    })
                    .collect();
                let mut outs = vec![StepOutcome::default(); width];
                let mut want = StepOutcome::default();
                for step in 0..8 {
                    batch.step_installed_into(&mut outs);
                    for (r, solo) in solos.iter_mut().enumerate() {
                        solo.step_installed_into(&mut want);
                        assert_outcomes_eq(
                            &outs[r],
                            &want,
                            &format!(
                                "{} policy {pi} width {width} \
                                 step {step} replica {r}",
                                kind.name()
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn drop_heavy_dropcomm_batches_stay_bitwise_and_actually_drop() {
    // a tight bounded-wait deadline under heavy stragglers: most steps
    // take the scalar fallback (survivor restart), the rest ride the
    // lockstep pass, and every lane stays bitwise either way
    for kind in TopologyKind::ALL {
        let mut cfg = cfg(kind, 8);
        cfg.stragglers = StragglerKind::Uniform { p: 0.45, delay: 5.0 };
        let policy = DropPolicy::comm_deadline(0.6);
        let seeds = [11u64, 22, 33, 44, 55];
        let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
        let mut solos: Vec<ClusterSim> = seeds
            .iter()
            .map(|&s| ClusterSim::new(&cfg, s).with_policy(policy.clone()))
            .collect();
        let mut outs = vec![StepOutcome::default(); seeds.len()];
        let mut want = StepOutcome::default();
        let (mut dropped, mut clean) = (0usize, 0usize);
        for step in 0..15 {
            batch.step_installed_into(&mut outs);
            for (r, solo) in solos.iter_mut().enumerate() {
                solo.step_installed_into(&mut want);
                assert_outcomes_eq(
                    &outs[r],
                    &want,
                    &format!("{} step {step} replica {r}", kind.name()),
                );
                if want.total_completed()
                    < cfg.workers * cfg.accumulations
                {
                    dropped += 1;
                } else {
                    clean += 1;
                }
            }
        }
        assert!(dropped > 0, "{}: deadline must drop steps", kind.name());
        assert!(
            clean > 0,
            "{}: some replica-steps must stay on the lockstep path",
            kind.name()
        );
    }
}

#[test]
fn churned_fault_plan_batches_stay_bitwise() {
    // kills, rejoins, slowdowns and drift change live membership and
    // per-worker speed mid-run; dead-seat steps fall back to the scalar
    // finish and rejoin steps return to the lockstep pass, bitwise
    // throughout
    let plan = FaultPlan::parse(
        "fail@3:w1,rejoin+4;slow@2:w0,x2.5,for5;drift@6:w3,+0.1",
    )
    .expect("valid plan");
    for kind in [TopologyKind::Ring, TopologyKind::Torus { rows: 0 }] {
        let cfg = cfg(kind, 6);
        let policy = DropPolicy::compute_tau(5.0);
        let seeds = [5u64, 6, 7, 8];
        let build = |seed: u64| {
            ClusterSim::new(&cfg, seed)
                .with_policy(policy.clone())
                .with_fault_plan(plan.clone())
        };
        let mut batch = ReplicaBatch::from_sims(
            seeds.iter().map(|&s| build(s)).collect(),
        );
        let mut solos: Vec<ClusterSim> =
            seeds.iter().map(|&s| build(s)).collect();
        let mut outs = vec![StepOutcome::default(); seeds.len()];
        let mut want = StepOutcome::default();
        for step in 0..16 {
            batch.step_installed_into(&mut outs);
            for (r, solo) in solos.iter_mut().enumerate() {
                solo.step_installed_into(&mut want);
                assert_outcomes_eq(
                    &outs[r],
                    &want,
                    &format!("{} step {step} replica {r}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn replay_sourced_batches_stay_bitwise() {
    // record three live runs (distinct seeds, same shape), then batch
    // their replay sims: the recorded draws drive the lockstep pass and
    // every lane reproduces its recorded outcomes bitwise
    let cfg = cfg(TopologyKind::Ring, 6);
    let policy = DropPolicy::compute_tau(4.5);
    let steps = 10usize;
    let mut traces = Vec::new();
    for seed in [0x71A1u64, 0x71A2, 0x71A3] {
        let mut live =
            ClusterSim::new(&cfg, seed).with_policy(policy.clone());
        live.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..steps {
            live.step_installed_into(&mut out);
        }
        traces.push(live.finish_recording().expect("consistent recording"));
    }
    let sims: Vec<ClusterSim> = traces
        .iter()
        .map(|t| ClusterSim::from_trace(t).expect("valid trace"))
        .collect();
    let mut batch = ReplicaBatch::from_sims(sims);
    let mut outs = vec![StepOutcome::default(); traces.len()];
    for step in 0..steps {
        batch.step_installed_into(&mut outs);
        for (r, trace) in traces.iter().enumerate() {
            let rec = &trace.outcomes[step];
            assert!(
                rec.matches(&outs[r]),
                "batched replay must reproduce the recorded outcome \
                 bitwise (step {step}, replica {r})"
            );
        }
    }
}

#[test]
fn sweep_results_bitwise_independent_of_batch_and_jobs() {
    // the rewired seed axis: whatever (jobs, batch) pair runs the grid,
    // every SweepPoint carries the serial scalar run's bits — including
    // a ragged tail (5 seeds at widths 2, 3 and 8)
    let mut base = cfg(TopologyKind::Ring, 4);
    base.stragglers = StragglerKind::Uniform { p: 0.3, delay: 3.0 };
    let policies = [
        DropPolicy::None,
        DropPolicy::compute_tau(2.0),
        DropPolicy::parse("tau=2+deadline=0.8").expect("valid spec"),
    ];
    let spec = SweepSpec::new(base)
        .workers(&[4, 6])
        .policies(&policies)
        .seeds(&[1, 2, 3, 4, 5])
        .iters(6)
        .progress(false);
    let reference = spec.clone().jobs(1).batch(1).run();
    assert_eq!(reference.points.len(), spec.len());
    for (jobs, batch) in [(1, 3), (4, 3), (2, 8), (0, 2), (1, 5)] {
        let got = spec.clone().jobs(jobs).batch(batch).run();
        assert_eq!(reference.points.len(), got.points.len());
        for (a, b) in reference.points.iter().zip(&got.points) {
            assert_eq!(a.index, b.index, "jobs={jobs} batch={batch}");
            assert_eq!((a.workers, a.seed), (b.workers, b.seed));
            assert_eq!(a.policy, b.policy);
            for (x, y) in [
                (a.mean_iter_time, b.mean_iter_time),
                (a.mean_compute_time, b.mean_compute_time),
                (a.throughput, b.throughput),
                (a.drop_rate, b.drop_rate),
            ] {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "jobs={jobs} batch={batch} point {}",
                    a.index
                );
            }
        }
    }
}

#[test]
fn sweep_observed_output_bitwise_independent_of_batch() {
    // live observers route through the scalar oracle per replica, so
    // the per-point shards and the merged histograms cannot depend on
    // the batch width (or the thread count)
    let base = cfg(TopologyKind::Ring, 5);
    let spec = SweepSpec::new(base)
        .workers(&[5])
        .policies(&[
            DropPolicy::None,
            DropPolicy::parse("tau=2+deadline=0.8").expect("valid spec"),
        ])
        .seeds(&[1, 2, 3])
        .iters(8)
        .progress(false);
    let (r1, o1) = spec.clone().jobs(1).batch(1).run_observed();
    let (r2, o2) = spec.clone().jobs(3).batch(2).run_observed();
    for (a, b) in r1.points.iter().zip(&r2.points) {
        assert_eq!(a.mean_iter_time.to_bits(), b.mean_iter_time.to_bits());
        assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits());
    }
    assert_eq!(o1.per_point.len(), o2.per_point.len());
    for (i, (a, b)) in o1.per_point.iter().zip(&o2.per_point).enumerate() {
        assert_eq!(a.steps, b.steps, "point {i}");
        assert_eq!(
            a.iter_time.sum().to_bits(),
            b.iter_time.sum().to_bits(),
            "point {i}"
        );
        assert_eq!(a.drops, b.drops, "point {i}");
    }
    let (a, b) = (&o1.merged, &o2.merged);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.drops, b.drops);
    for (ha, hb) in [
        (&a.iter_time, &b.iter_time),
        (&a.compute_time, &b.compute_time),
        (&a.arrival_offset, &b.arrival_offset),
    ] {
        assert_eq!(ha.count(), hb.count());
        assert_eq!(ha.sum().to_bits(), hb.sum().to_bits());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(
                ha.percentile(q).to_bits(),
                hb.percentile(q).to_bits(),
                "q={q}"
            );
        }
    }
}

#[test]
fn batched_fills_reproduce_each_replica_stream_draw_for_draw() {
    // the RNG stream-isolation contract at the fill level, across
    // replicas: the batched step draws each replica's workers through
    // fill_microbatches(_bounded) replica-by-replica, and each call
    // must reproduce that worker's sequential draws — values, the
    // bounded fill's early-stop point, and the end-of-stream RNG state
    let config = cfg(TopologyKind::Ring, 4);
    let model = LatencyModel::from_config(&config);
    let accums = 7usize;
    for (tau, label) in
        [(f64::INFINITY, "unbounded"), (2.0, "bounded"), (0.05, "tight")]
    {
        // one independent stream set per replica, built like ClusterSim
        let seeds = [0xF00u64, 0xF01, 0xF02];
        for (rep, &seed) in seeds.iter().enumerate() {
            let root = Xoshiro256pp::seed_from_u64(seed);
            for w in 0..config.workers {
                let mut batched: Xoshiro256pp = root.split(w as u64);
                let mut seq = batched.clone();
                let mut buf = Vec::new();
                let drawn = model.fill_microbatches_bounded(
                    w, 0.0, tau, accums, &mut buf, &mut batched,
                );
                // sequential reference: draw until the running total
                // crosses tau, exactly one sample past the crossing
                let mut t = 0.0;
                let mut want = Vec::new();
                for _ in 0..accums {
                    let s = model.sample_microbatch(w, &mut seq);
                    want.push(s);
                    t += s;
                    if t >= tau {
                        break;
                    }
                }
                assert_eq!(
                    drawn,
                    want.len(),
                    "{label} replica {rep} worker {w}: early-stop point"
                );
                for (i, (a, b)) in buf.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{label} replica {rep} worker {w} draw {i}"
                    );
                }
                // end-of-stream state: the next raw word agrees
                assert_eq!(
                    batched.next_u64(),
                    seq.next_u64(),
                    "{label} replica {rep} worker {w}: stream position"
                );
            }
        }
    }
}

#[test]
fn batch_leaves_replica_streams_where_solo_stepping_leaves_them() {
    // end-of-stream at the ClusterSim level: step a batch, dissolve it,
    // keep stepping each replica solo — outcomes must stay bitwise
    // equal to replicas that were never batched, which they can only do
    // if batched stepping left every RNG stream in the solo position
    let cfg = cfg(TopologyKind::Tree, 7);
    let policy = DropPolicy::parse("tau=4+deadline=1.2").expect("valid");
    let seeds = [100u64, 200, 300];
    let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
    let mut solos: Vec<ClusterSim> = seeds
        .iter()
        .map(|&s| ClusterSim::new(&cfg, s).with_policy(policy.clone()))
        .collect();
    let mut outs = vec![StepOutcome::default(); seeds.len()];
    let mut want = StepOutcome::default();
    for _ in 0..6 {
        batch.step_installed_into(&mut outs);
        for solo in solos.iter_mut() {
            solo.step_installed_into(&mut want);
        }
    }
    let mut dissolved = batch.into_sims();
    for step in 6..14 {
        for (r, (sim, solo)) in
            dissolved.iter_mut().zip(&mut solos).enumerate()
        {
            sim.step_installed_into(&mut outs[r]);
            solo.step_installed_into(&mut want);
            assert_outcomes_eq(
                &outs[r],
                &want,
                &format!("post-batch step {step} replica {r}"),
            );
        }
    }
}

#[test]
fn scan_edge_cases_degenerate_schedules_stay_bitwise() {
    // zero/one-worker schedules have empty or trivial phase lists;
    // infinite straggler delays push +inf through the phase pass — the
    // batched lanes must still carry the scalar bits
    for kind in TopologyKind::ALL {
        for workers in [1usize, 2] {
            let cfg = cfg(kind, workers);
            let policy = DropPolicy::None;
            let seeds = [1u64, 2, 3];
            let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
            let mut solos: Vec<ClusterSim> = seeds
                .iter()
                .map(|&s| {
                    ClusterSim::new(&cfg, s).with_policy(policy.clone())
                })
                .collect();
            let mut outs = vec![StepOutcome::default(); seeds.len()];
            let mut want = StepOutcome::default();
            for step in 0..6 {
                batch.step_installed_into(&mut outs);
                for (r, solo) in solos.iter_mut().enumerate() {
                    solo.step_installed_into(&mut want);
                    assert_outcomes_eq(
                        &outs[r],
                        &want,
                        &format!(
                            "{} n={workers} step {step} replica {r}",
                            kind.name()
                        ),
                    );
                }
            }
        }
    }
    // +inf arrivals: an infinitely-delayed straggler saturates the
    // phase pass identically on both paths
    let mut inf_cfg = cfg(TopologyKind::Ring, 5);
    inf_cfg.stragglers = StragglerKind::Uniform {
        p: 0.4,
        delay: f64::INFINITY,
    };
    let seeds = [9u64, 10, 11, 12];
    let mut batch = ReplicaBatch::new(&inf_cfg, &DropPolicy::None, &seeds);
    let mut solos: Vec<ClusterSim> = seeds
        .iter()
        .map(|&s| ClusterSim::new(&inf_cfg, s))
        .collect();
    let mut outs = vec![StepOutcome::default(); seeds.len()];
    let mut want = StepOutcome::default();
    let mut saw_inf = false;
    for step in 0..8 {
        batch.step_installed_into(&mut outs);
        for (r, solo) in solos.iter_mut().enumerate() {
            solo.step_installed_into(&mut want);
            assert_outcomes_eq(
                &outs[r],
                &want,
                &format!("inf step {step} replica {r}"),
            );
            saw_inf |= want.iter_time.is_infinite();
        }
    }
    assert!(saw_inf, "the infinite delay must actually land");
}

#[test]
fn scan_max4_bitwise_equals_sequential_fold_on_adversarial_inputs() {
    // ragged tails, NaN / +-inf mixes, empty input — then a fuzz loop
    let mut cases: Vec<Vec<f64>> = vec![
        vec![],
        vec![2.25],
        vec![f64::NAN],
        vec![f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN],
        vec![f64::NEG_INFINITY, f64::INFINITY, 0.0],
        vec![0.0, -1.5, f64::INFINITY, 3.0, f64::NAN, 7.5, 2.0],
        vec![f64::NEG_INFINITY; 9],
    ];
    // every ragged tail length around the 4-wide chunking
    for n in 0..=17 {
        cases.push((0..n).map(|i| ((i * 31) % 13) as f64 * 0.375).collect());
    }
    let mut rng = Xoshiro256pp::seed_from_u64(0xFA22);
    for _ in 0..200 {
        let n = rng.next_below(40) as usize;
        cases.push(
            (0..n)
                .map(|_| match rng.next_below(8) {
                    0 => f64::NAN,
                    1 => f64::INFINITY,
                    2 => f64::NEG_INFINITY,
                    _ => (rng.next_f64() - 0.25) * 50.0,
                })
                .collect(),
        );
    }
    for xs in &cases {
        let want = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(scan_max4(xs).to_bits(), want.to_bits(), "{xs:?}");
    }
}

#[test]
fn fuzzed_link_params_keep_batched_lanes_bitwise() {
    // random link parameter triples (latency, bandwidth, bytes spanning
    // several orders of magnitude), random topology and width: the SoA
    // pass must stay a bitwise mirror of the scalar pass whatever hop
    // values the schedule compiles to
    let mut rng = Xoshiro256pp::seed_from_u64(0x11_4B);
    for case in 0..25 {
        let kind = TopologyKind::ALL[rng.next_below(4) as usize];
        let workers = 2 + rng.next_below(9) as usize;
        let width = 1 + rng.next_below(6) as usize;
        let mut cfg = cfg(kind, workers);
        cfg.link_latency = 1e-6 * 10f64.powi(rng.next_below(4) as i32);
        cfg.link_bandwidth = 1e7 * 10f64.powi(rng.next_below(4) as i32);
        cfg.grad_bytes = 1e4 * 10f64.powi(rng.next_below(5) as i32);
        let policy = DropPolicy::compute_tau(3.0);
        let seeds: Vec<u64> =
            (0..width as u64).map(|r| rng.next_u64() ^ r).collect();
        let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
        let mut solos: Vec<ClusterSim> = seeds
            .iter()
            .map(|&s| ClusterSim::new(&cfg, s).with_policy(policy.clone()))
            .collect();
        let mut outs = vec![StepOutcome::default(); width];
        let mut want = StepOutcome::default();
        for step in 0..4 {
            batch.step_installed_into(&mut outs);
            for (r, solo) in solos.iter_mut().enumerate() {
                solo.step_installed_into(&mut want);
                assert_outcomes_eq(
                    &outs[r],
                    &want,
                    &format!(
                        "case {case} {} n={workers} width={width} \
                         step {step} replica {r}",
                        kind.name()
                    ),
                );
            }
        }
    }
}
