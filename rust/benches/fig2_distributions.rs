//! Figure 2 — reduction in variance and mean iteration time.
//! (left) per-worker step time T_n distribution without DropCompute;
//! (right) max-over-workers T distribution at several drop rates, with
//! the per-worker-normal "simulation" overlay the paper draws dashed.

mod common;

use common::{header, paper_cluster};
use dropcompute::analysis::threshold_for_drop_rate;
use dropcompute::report::{f, pct, Table};
use dropcompute::rng::{Distribution, Normal, Xoshiro256pp};
use dropcompute::sim::ClusterSim;
use dropcompute::stats::{Histogram, Welford};

fn main() {
    header(
        "Figure 2 — iteration time distribution, 200 workers",
        "DropCompute clips the straggler tail: higher drop rate => \
         narrower max-T distribution with smaller mean",
    );
    let cfg = paper_cluster(200);
    let iters = 120;

    // ---- left: T_n across all workers, no drops --------------------
    let mut sim = ClusterSim::new(&cfg, 21);
    let trace = sim.record_trace(iters);
    let mut worker_w = Welford::new();
    let mut hist = Histogram::new(5.0, 14.0, 36);
    // per-worker moments for the normal-overlay "simulation"
    let mut per_worker: Vec<Welford> = (0..200).map(|_| Welford::new()).collect();
    for i in 0..iters {
        for n in 0..200 {
            let t = trace.worker_step_time(i, n);
            worker_w.push(t);
            hist.push(t);
            per_worker[n].push(t);
        }
    }
    println!("\nFig 2 (left) — step time T_n of all workers (no drops)");
    println!("  mean {:.2}s  std {:.2}s  p99 ~{:.2}s", worker_w.mean(),
             worker_w.std(), worker_w.max());
    println!("  [5.0s .. 14.0s] {}", hist.sparkline());

    // ---- right: max-over-workers T at several drop rates -----------
    let rates = [0.0, 0.01, 0.05, 0.10];
    let mut t = Table::new(
        "Fig 2 (right) — max iteration time T vs drop rate",
        &["drop rate", "tau", "mean T", "std T", "histogram [5..14s]"],
    );
    for &rate in &rates {
        let tau = if rate == 0.0 {
            f64::INFINITY
        } else {
            threshold_for_drop_rate(&trace, rate)
        };
        let mut sim = ClusterSim::new(&cfg, 22);
        let mut w = Welford::new();
        let mut h = Histogram::new(5.0, 14.0, 36);
        for _ in 0..iters {
            let out = sim.step(if tau.is_finite() { Some(tau) } else { None });
            w.push(out.compute_time);
            h.push(out.compute_time);
        }
        t.row(vec![
            pct(rate),
            if tau.is_finite() { f(tau, 2) } else { "inf".into() },
            f(w.mean(), 3),
            f(w.std(), 3),
            h.sparkline(),
        ]);
    }
    t.print();

    // ---- dashed overlay: draw T_n ~ N(mean_n, var_n) i.i.d. --------
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    let mut w_sim = Welford::new();
    for _ in 0..iters {
        let mut mx = f64::NEG_INFINITY;
        for pw in &per_worker {
            let d = Normal::new(pw.mean(), pw.std());
            mx = mx.max(d.sample(&mut rng));
        }
        w_sim.push(mx);
    }
    let mut sim2 = ClusterSim::new(&cfg, 23);
    let mut w_real = Welford::new();
    for _ in 0..iters {
        w_real.push(sim2.step(None).compute_time);
    }
    println!(
        "normal-overlay 'simulation' of max T: mean {:.2}s vs measured {:.2}s \
         (the paper's dashed curve matches when tails are light)",
        w_sim.mean(),
        w_real.mean()
    );

    // shape checks: clipping narrows and lowers the distribution
    let tau10 = threshold_for_drop_rate(&trace, 0.10);
    let mut sim3 = ClusterSim::new(&cfg, 22);
    let mut w10 = Welford::new();
    for _ in 0..iters {
        w10.push(sim3.step(Some(tau10)).compute_time);
    }
    assert!(w10.mean() < w_real.mean(), "drops must reduce mean max-T");
    assert!(w10.std() < w_real.std(), "drops must reduce max-T variance");
    println!("\nSHAPE CHECK PASSED: 10% drops cut mean max-T {:.2}s -> {:.2}s, \
              std {:.2}s -> {:.2}s",
        w_real.mean(), w10.mean(), w_real.std(), w10.std());
}
