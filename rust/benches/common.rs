#![allow(dead_code)]
//! Shared bench plumbing: the paper's simulated-delay cluster config.

use dropcompute::config::{ClusterConfig, NoiseKind};

/// The App. B.1 simulated-delay environment around a 0.45s micro-batch.
pub fn paper_noise() -> NoiseKind {
    NoiseKind::PaperLogNormal {
        mu: 4.0,
        sigma: 1.0,
        alpha: 2.0 * (4.5f64).exp(),
        beta: 5.5,
    }
}

/// BERT-1.5B-like cluster shape: M=12 accumulations, T^c=0.5s.
pub fn paper_cluster(workers: usize) -> ClusterConfig {
    ClusterConfig {
        workers,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.5,
        noise: paper_noise(),
        ..Default::default()
    }
}

/// Section header shared by every bench.
pub fn header(id: &str, paper_claim: &str) {
    println!("\n================================================================");
    println!("{id}");
    println!("paper: {paper_claim}");
    println!("================================================================");
}
