//! Figure 13 (App. C.3) — the noise *distribution type* determines how
//! much DropCompute can help: five families with identical mean (0.225)
//! and (where possible) variance 0.05, plus the paper's diagnostic
//! E[T]/E[T_i] ratio.

mod common;

use common::header;
use dropcompute::config::{ClusterConfig, NoiseKind};
use dropcompute::coordinator::ScaleRun;
use dropcompute::report::{f, Table};
use dropcompute::sim::ClusterSim;

fn cluster(noise: NoiseKind) -> ClusterConfig {
    ClusterConfig {
        workers: 1,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.01,
        comm_latency: 0.5,
        noise,
        ..Default::default()
    }
}

/// E[T]/E[T_i]: expected max-over-workers step time over expected
/// single-worker step time — the paper's potential-gain indicator.
fn ratio(cfg: &ClusterConfig, workers: usize) -> f64 {
    let mut single = cfg.clone();
    single.workers = 1;
    let mut s1 = ClusterSim::new(&single, 131);
    let t1: f64 =
        (0..150).map(|_| s1.step(None).compute_time).sum::<f64>() / 150.0;
    let mut many = cfg.clone();
    many.workers = workers;
    let mut sn = ClusterSim::new(&many, 132);
    let tn: f64 =
        (0..150).map(|_| sn.step(None).compute_time).sum::<f64>() / 150.0;
    tn / t1
}

fn main() {
    header(
        "Figure 13 — noise distribution type vs DropCompute effectiveness",
        "heavier tails => larger E[T]/E[T_i] => more recoverable time; \
         lognormal gains most, bernoulli/normal least (paper's table: \
         1.496 / 1.302 / 1.283 / 1.386 / 1.39 at its scale)",
    );
    let fams: Vec<(&str, NoiseKind)> = vec![
        ("lognormal", NoiseKind::LogNormal { mean: 0.225, var: 0.05 }),
        ("normal", NoiseKind::Normal { mean: 0.225, var: 0.05 }),
        ("bernoulli", NoiseKind::Bernoulli { p: 0.5, value: 0.45 }),
        ("exponential", NoiseKind::Exponential { mean: 0.225 }),
        ("gamma", NoiseKind::Gamma { mean: 0.225, var: 0.05 }),
    ];

    let ns = [16usize, 64, 200];
    let mut t = Table::new(
        "Fig 13 — per-family scale behaviour (N=200) and E[T]/E[T_i] (N=64)",
        &["family", "E[T]/E[T_i]", "base eff N=200", "dc eff N=200", "speedup"],
    );
    // Every family's measurement is independent — fan them over the
    // sweep engine's deterministic parallel runner.
    let n_big = *ns.last().unwrap();
    let fams_run = fams.clone();
    let measured = dropcompute::sweep::run_indexed(
        fams_run.len(),
        0,
        Some("fig13"),
        move |i| {
            let (name, noise) = fams_run[i].clone();
            let cfg = cluster(noise);
            let r = ratio(&cfg, 64);
            let run = ScaleRun {
                base: cfg,
                calibration_iters: 12,
                measure_iters: 50,
                grid: 128,
                seed: 133,
                ..ScaleRun::default()
            };
            (name, r, run.point(n_big))
        },
    );
    let mut ratios = Vec::new();
    for (name, r, p) in &measured {
        t.row(vec![
            name.to_string(),
            f(*r, 3),
            f(p.baseline_throughput / p.linear_throughput, 3),
            f(p.dropcompute_throughput / p.linear_throughput, 3),
            f(p.dropcompute_throughput / p.baseline_throughput, 3),
        ]);
        ratios.push((name.to_string(), *r,
                     p.dropcompute_throughput / p.baseline_throughput));
    }
    t.print();

    // shape: lognormal (heavy tail) has the largest ratio of the
    // equal-variance families, and ratio correlates with speedup.
    let get = |n: &str| ratios.iter().find(|r| r.0 == n).unwrap().clone();
    let lognormal = get("lognormal");
    let normal = get("normal");
    let bernoulli = get("bernoulli");
    assert!(
        lognormal.1 > normal.1 && lognormal.1 > bernoulli.1,
        "lognormal should have the largest E[T]/E[T_i]: {ratios:?}"
    );
    assert!(
        lognormal.2 > normal.2 * 0.99,
        "lognormal speedup should top normal: {ratios:?}"
    );
    println!("\nSHAPE CHECK PASSED: tail weight ranks recoverable time \
              (lognormal ratio {:.3} > normal {:.3}, bernoulli {:.3})",
             lognormal.1, normal.1, bernoulli.1);
}
