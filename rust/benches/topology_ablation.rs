//! Topology ablation — the four-way drop sweep over collective shapes.
//!
//! For each topology (ring, tree, hierarchical, torus) and cluster size
//! N, measures useful throughput under:
//!   * no-drop        — plain synchronous training
//!   * DropCompute    — compute threshold tau* (Algorithm 2)
//!   * DropComm       — bounded-wait AllReduce (membership closes
//!                      `DEADLINE` after the first arrival)
//!   * both           — the two drops composed
//!
//! and emits a JSON document (validated in-process with the crate's own
//! parser) of speedups vs the no-drop baseline — the comparison the
//! paper's runtime model cannot express because it folds communication
//! into one constant.
//!
//! A second section sweeps a single [`SweepSpec::policies`] axis per
//! topology — none / tau / step-level deadline / OptiReduce-style
//! per-phase deadline / composed — the ablation the legacy
//! thresholds × deadlines grid could not spell.

mod common;

use common::{header, paper_cluster};
use dropcompute::coordinator::ScaleRun;
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, Table};
use dropcompute::runtime::json::Json;
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

/// DropComm membership deadline (s after first arrival). The paper's
/// lognormal delay spreads worker step times by a few seconds around
/// ~6.6s; 3s sheds the straggling tail while keeping the bulk of the
/// cohort in the reduction.
const DEADLINE: f64 = 3.0;

struct Cell {
    n: usize,
    no_drop: f64,
    drop_compute: f64,
    drop_comm: f64,
    both: f64,
}

fn measure(kind: TopologyKind, n: usize) -> Cell {
    let mut base = paper_cluster(1);
    base.topology = Some(kind);
    // keep the event-driven collective in the same ballpark as the
    // paper's T^c=0.5s so compute and comm both matter
    base.link_latency = 25e-6;
    base.link_bandwidth = 12.5e9;
    base.grad_bytes = 4.0 * 335e6; // 335M-param fp32 gradient
    let plain = ScaleRun {
        base: base.clone(),
        calibration_iters: 10,
        measure_iters: 30,
        grid: 96,
        seed: 0x7070 + n as u64,
        comm_drop_deadline: None,
        // the cells themselves fan out over the pool; each cell's
        // 3-point inner sweep stays serial
        jobs: 1,
    };
    let bounded = ScaleRun {
        comm_drop_deadline: Some(DEADLINE),
        base,
        ..plain
    };
    let p = plain.point(n);
    let b = bounded.point(n);
    Cell {
        n,
        no_drop: p.baseline_throughput,
        drop_compute: p.dropcompute_throughput,
        drop_comm: b.baseline_throughput,
        both: b.dropcompute_throughput,
    }
}

fn main() {
    header(
        "Topology ablation — compute-side vs comm-side dropping",
        "DropCompute (paper, Alg. 1) caps compute tails; DropComm \
         (bounded-wait AllReduce) caps collective tails; hierarchical \
         topologies shorten the dependency chains a straggler can stall",
    );

    let ns = [8usize, 24, 48];
    let mut json = String::from("{\n  \"bench\": \"topology_ablation\",\n");
    json.push_str(&format!("  \"comm_drop_deadline\": {DEADLINE},\n"));
    json.push_str("  \"topologies\": [\n");

    // The full topology x N grid fans out over the sweep engine's
    // deterministic parallel runner (every cell derives its seeds from
    // its own coordinates, so the order of execution is invisible).
    let grid: Vec<(TopologyKind, usize)> = TopologyKind::ALL
        .iter()
        .flat_map(|&k| ns.iter().map(move |&n| (k, n)))
        .collect();
    let n_cells = grid.len();
    let mut measured: Vec<Cell> = dropcompute::sweep::run_indexed(
        n_cells,
        0,
        Some("topology_ablation"),
        move |i| {
            let (kind, n) = grid[i];
            measure(kind, n)
        },
    );

    let mut all_cells: Vec<(&'static str, Vec<Cell>)> = Vec::new();
    for (ti, kind) in TopologyKind::ALL.iter().enumerate() {
        let cells: Vec<Cell> =
            measured.drain(..ns.len()).collect();

        let mut t = Table::new(
            format!("useful throughput (mb/s) — {} topology", kind.name()),
            &["N", "no-drop", "DropCompute", "DropComm", "both",
              "speedup DC", "speedup comm", "speedup both"],
        );
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"points\": [\n",
            kind.name()
        ));
        for (ci, c) in cells.iter().enumerate() {
            t.row(vec![
                c.n.to_string(),
                f(c.no_drop, 1),
                f(c.drop_compute, 1),
                f(c.drop_comm, 1),
                f(c.both, 1),
                f(c.drop_compute / c.no_drop, 3),
                f(c.drop_comm / c.no_drop, 3),
                f(c.both / c.no_drop, 3),
            ]);
            json.push_str(&format!(
                "      {{\"n\": {}, \"no_drop\": {:.4}, \
                 \"drop_compute\": {:.4}, \"drop_comm\": {:.4}, \
                 \"both\": {:.4}, \"speedup_drop_compute\": {:.4}, \
                 \"speedup_drop_comm\": {:.4}, \"speedup_both\": {:.4}}}{}\n",
                c.n,
                c.no_drop,
                c.drop_compute,
                c.drop_comm,
                c.both,
                c.drop_compute / c.no_drop,
                c.drop_comm / c.no_drop,
                c.both / c.no_drop,
                if ci + 1 < cells.len() { "," } else { "" },
            ));
        }
        t.print();
        json.push_str(&format!(
            "    ]}}{}\n",
            if ti + 1 < TopologyKind::ALL.len() { "," } else { "" }
        ));
        all_cells.push((kind.name(), cells));
    }
    json.push_str("  ],\n");

    // ---- policy ablation: one SweepSpec::policies axis ---------------
    // The unified drop surface sweeps arms the legacy
    // thresholds x deadlines grid cannot express — per-phase deadlines
    // (OptiReduce-style mid-collective cutoffs) next to tau, step-level
    // DropComm and their composition — as ONE axis, per topology.
    let policy_axis: Vec<DropPolicy> = [
        "none".to_string(),
        "tau=9".to_string(),
        format!("deadline={DEADLINE}"),
        format!("phase-deadline={DEADLINE}/0.5/0.5"),
        format!("tau=9+deadline={DEADLINE}"),
    ]
    .iter()
    .map(|s| DropPolicy::parse(s).expect("bench policy specs are valid"))
    .collect();
    const POLICY_N: usize = 24;
    json.push_str("  \"policy_ablation\": [\n");
    let mut policy_tables = Vec::new();
    for (ti, kind) in TopologyKind::ALL.iter().enumerate() {
        let mut base = paper_cluster(POLICY_N);
        base.topology = Some(*kind);
        base.link_latency = 25e-6;
        base.link_bandwidth = 12.5e9;
        base.grad_bytes = 4.0 * 335e6;
        let result = SweepSpec::new(base)
            .workers(&[POLICY_N])
            .policies(&policy_axis)
            .seeds(&[0x90_11C + ti as u64])
            .iters(30)
            .jobs(0)
            .progress(false)
            .run();
        let mut t = Table::new(
            format!("policy ablation — {} topology, N={POLICY_N}", kind.name()),
            &["policy", "iter time", "mb/s", "drop"],
        );
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"points\": [\n",
            kind.name()
        ));
        for (pi, p) in result.points.iter().enumerate() {
            let spec = p.policy.as_deref().expect("policy axis");
            t.row(vec![
                spec.to_string(),
                f(p.mean_iter_time, 3),
                f(p.throughput, 1),
                f(p.drop_rate * 100.0, 1),
            ]);
            json.push_str(&format!(
                "      {{\"policy\": \"{}\", \"mean_iter_time\": {:.4}, \
                 \"throughput\": {:.4}, \"drop_rate\": {:.4}}}{}\n",
                spec,
                p.mean_iter_time,
                p.throughput,
                p.drop_rate,
                if pi + 1 < result.points.len() { "," } else { "" },
            ));
        }
        t.print();
        json.push_str(&format!(
            "    ]}}{}\n",
            if ti + 1 < TopologyKind::ALL.len() { "," } else { "" }
        ));
        policy_tables.push((kind.name(), result));
    }
    json.push_str("  ]\n}\n");

    println!("JSON_BEGIN");
    print!("{json}");
    println!("JSON_END");

    // The emitted document must be machine-readable by the crate's own
    // parser and cover every topology x variant x N combination.
    let doc = Json::parse(&json).expect("bench must emit valid JSON");
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert_eq!(topos.len(), TopologyKind::ALL.len());
    for t in topos {
        assert_eq!(
            t.get("points").unwrap().as_arr().unwrap().len(),
            ns.len()
        );
    }
    // ...including the policy-axis ablation, with the per-phase arm
    // present for every topology.
    let pa = doc.get("policy_ablation").unwrap().as_arr().unwrap();
    assert_eq!(pa.len(), TopologyKind::ALL.len());
    for t in pa {
        let pts = t.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), policy_axis.len());
        assert!(
            pts.iter().any(|p| p
                .get("policy")
                .and_then(Json::as_str)
                .is_some_and(|s| s.starts_with("phase-deadline="))),
            "per-phase arm missing from the policy ablation"
        );
    }
    // Shape: both arms share the seed (paired arrivals), and the
    // per-phase arm's checkpoints are a superset of the step-level
    // entry check — so it can only drop at least as much, while its
    // extra mid-collective cutoffs must not collapse throughput.
    for (name, result) in &policy_tables {
        let by = |prefix: &str| {
            result
                .points
                .iter()
                .find(|p| {
                    p.policy.as_deref().is_some_and(|s| s.starts_with(prefix))
                })
                .expect("axis arm present")
        };
        let step = by("deadline=");
        let phase = by("phase-deadline=");
        assert!(
            phase.drop_rate >= step.drop_rate - 1e-12,
            "{name}: per-phase checkpoints subsume the entry check \
             ({} vs {})",
            phase.drop_rate,
            step.drop_rate
        );
        assert!(
            phase.throughput > 0.5 * step.throughput,
            "{name}: per-phase arm collapsed ({} vs {})",
            phase.throughput,
            step.throughput
        );
    }

    // Shape checks: dropping (either side) should not lose much useful
    // throughput anywhere, and at the largest N the composed variant
    // should beat the plain baseline for every topology.
    for (name, cells) in &all_cells {
        for c in cells {
            assert!(
                c.drop_compute > 0.9 * c.no_drop,
                "{name} N={}: DropCompute lost throughput ({} vs {})",
                c.n, c.drop_compute, c.no_drop
            );
            assert!(
                c.drop_comm > 0.6 * c.no_drop,
                "{name} N={}: DropComm lost too much throughput ({} vs {})",
                c.n, c.drop_comm, c.no_drop
            );
            assert!(
                c.both > 0.9 * c.no_drop,
                "{name} N={}: composed variant lost throughput ({} vs {})",
                c.n, c.both, c.no_drop
            );
        }
        let last = cells.last().unwrap();
        assert!(
            last.both >= 0.95 * last.no_drop,
            "{name}: at N={} composed drops should roughly match or beat \
             no-drop ({} vs {})",
            last.n, last.both, last.no_drop
        );
    }
    println!(
        "\nSHAPE CHECK PASSED: {} topologies x {} sizes x 4 variants, \
         + policy axis ({} arms incl. per-phase deadlines)",
        all_cells.len(),
        ns.len(),
        policy_axis.len()
    );
}
