//! Figure 12 (App. B.3) — DropCompute integrates with Local-SGD:
//! speedup over synchronous training vs synchronization period H, in
//! two straggler scenarios (uniform, single-server), 32 workers, 4%
//! straggler probability per local step, 1s delay.

mod common;

use common::header;
use dropcompute::config::{ClusterConfig, StragglerKind};
use dropcompute::report::{f, Table};
use dropcompute::sim::ClusterSim;

fn cluster(stragglers: StragglerKind) -> ClusterConfig {
    ClusterConfig {
        workers: 32,
        accumulations: 1,
        microbatch_mean: 0.25,
        microbatch_std: 0.01,
        comm_latency: 0.15,
        stragglers,
        ..Default::default()
    }
}

/// Mean time per local step for each strategy — via the buffer-reusing
/// [`ClusterSim::mean_period_time`], so the measurement loop allocates
/// nothing per period.
fn measure(cfg: &ClusterConfig, h: usize, tau: Option<f64>, seed: u64) -> f64 {
    let mut sim = ClusterSim::new(cfg, seed);
    let periods = (120 / h.max(1)).max(20);
    sim.mean_period_time(periods, h, tau) / h as f64
}

/// Fully synchronous = sync every local step (H=1).
fn main() {
    header(
        "Figure 12 — Local-SGD ± DropCompute under stragglers",
        "Local-SGD amortizes uniform stragglers with growing H but not \
         single-server stragglers; DropCompute helps both",
    );
    let tau = 0.8; // drops ~the straggling (1s-delayed) local steps

    for (name, strag) in [
        ("uniform stragglers", StragglerKind::Uniform { p: 0.04, delay: 1.0 }),
        (
            "single server stragglers",
            StragglerKind::SingleServer { p: 0.04 * 4.0, delay: 1.0, server_size: 8 },
        ),
    ] {
        let cfg = cluster(strag);
        let sync = measure(&cfg, 1, None, 121);
        let mut t = Table::new(
            format!("Fig 12 — {name} (speedup vs synchronous)"),
            &["H", "Local-SGD", "Local-SGD + DropCompute"],
        );
        let mut rows = Vec::new();
        for h in [2usize, 4, 8, 16] {
            let plain = sync / measure(&cfg, h, None, 122 + h as u64);
            let dc = sync / measure(&cfg, h, Some(tau), 123 + h as u64);
            t.row(vec![h.to_string(), f(plain, 3), f(dc, 3)]);
            rows.push((h, plain, dc));
        }
        t.print();

        // shape: DropCompute >= plain at every H
        for &(h, plain, dc) in &rows {
            assert!(
                dc >= plain * 0.98,
                "{name} H={h}: dc {dc} should match/beat plain {plain}"
            );
        }
    }
    println!("\nSHAPE CHECK PASSED: DropCompute improves Local-SGD robustness");
}
