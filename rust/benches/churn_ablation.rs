//! Churn ablation — graceful degradation under dynamic membership.
//!
//! The scenario lab's headline question: how much useful throughput
//! does a synchronous cluster keep when workers fail, rejoin, and slow
//! down mid-run, and how much does a drop policy buy back? For each
//! topology (ring, tree, hierarchical, torus) the bench sweeps one
//! [`SweepSpec::scenarios`] axis — fault-free / transient fail+rejoin /
//! compound churn (permanent loss + transient loss + a 2x slowdown) —
//! against a policy axis (none / tau / tau+DropComm), all through the
//! same deterministic sweep engine the CLI uses, so every cell is
//! bitwise reproducible from its coordinates.
//!
//! Emits `BENCH_churn.json` (validated in-process with the crate's own
//! parser) for the CI artifact trail. `--smoke` shrinks the grid for
//! the scenario-smoke CI job.

mod common;

use common::{header, paper_cluster};
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, pct, Table};
use dropcompute::runtime::json::Json;
use dropcompute::sim::FaultPlan;
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

/// The churn axis: scripted fault plans in the `--scenario` grammar.
/// Worker ids are valid for every N the bench sweeps (smallest is 8).
fn scenario_axis(iters: usize) -> Vec<(&'static str, FaultPlan)> {
    // scale event steps with the horizon so smoke runs still see every
    // membership regime (fail, rejoin, compound churn)
    let q = (iters / 4).max(1);
    let transient = format!("fail@{q}:w2,rejoin+{q}");
    let compound = format!(
        "fail@{}:w0;fail@{q}:w1,rejoin+{q};slow@0:w3,x2.0",
        2 * q
    );
    vec![
        ("fault-free", FaultPlan::default()),
        (
            "transient",
            FaultPlan::parse(&transient).expect("bench scenario specs"),
        ),
        (
            "compound",
            FaultPlan::parse(&compound).expect("bench scenario specs"),
        ),
    ]
}

fn main() {
    header(
        "Churn ablation — drop policies under dynamic membership",
        "synchronous training stalls on its slowest member; DropCompute \
         (tau) and DropComm (bounded wait) must degrade gracefully — \
         not collapse — when the membership itself churns",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("(smoke mode: reduced grid/iters)");
    }
    let n = if smoke { 8 } else { 16 };
    let iters = if smoke { 20 } else { 60 };

    let policy_axis: Vec<DropPolicy> = ["none", "tau=9", "tau=9+deadline=3"]
        .iter()
        .map(|s| DropPolicy::parse(s).expect("bench policy specs"))
        .collect();
    let scenarios = scenario_axis(iters);
    let plans: Vec<FaultPlan> =
        scenarios.iter().map(|(_, p)| p.clone()).collect();

    let mut json = String::from("{\n  \"bench\": \"churn_ablation\",\n");
    json.push_str(&format!(
        "  \"workers\": {n}, \"iters\": {iters}, \"smoke\": {smoke},\n"
    ));
    json.push_str("  \"topologies\": [\n");

    for (ti, kind) in TopologyKind::ALL.iter().enumerate() {
        let mut base = paper_cluster(n);
        base.topology = Some(*kind);
        base.link_latency = 25e-6;
        base.link_bandwidth = 12.5e9;
        base.grad_bytes = 4.0 * 335e6;
        let result = SweepSpec::new(base)
            .workers(&[n])
            .policies(&policy_axis)
            .scenarios(&plans)
            .seeds(&[0xC4A0 + ti as u64])
            .iters(iters)
            .jobs(0)
            .progress(false)
            .run();
        assert_eq!(
            result.points.len(),
            policy_axis.len() * plans.len(),
            "policy x scenario grid"
        );
        let mut t = Table::new(
            format!("churn ablation — {} topology, N={n}", kind.name()),
            &["scenario", "policy", "iter time", "mb/s", "drop"],
        );
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"points\": [\n",
            kind.name()
        ));
        for (pi, p) in result.points.iter().enumerate() {
            let spec = p.scenario.as_deref().unwrap_or("none");
            let label = scenarios
                .iter()
                .find(|(_, plan)| plan.spec() == spec)
                .map(|(name, _)| *name)
                .unwrap_or("?");
            let policy = p.policy.as_deref().expect("policy axis");
            t.row(vec![
                label.to_string(),
                policy.to_string(),
                f(p.mean_iter_time, 3),
                f(p.throughput, 1),
                pct(p.drop_rate),
            ]);
            json.push_str(&format!(
                "      {{\"scenario\": \"{label}\", \"spec\": \"{spec}\", \
                 \"policy\": \"{policy}\", \"mean_iter_time\": {:.4}, \
                 \"throughput\": {:.4}, \"drop_rate\": {:.4}}}{}\n",
                p.mean_iter_time,
                p.throughput,
                p.drop_rate,
                if pi + 1 < result.points.len() { "," } else { "" },
            ));
        }
        t.print();
        json.push_str(&format!(
            "    ]}}{}\n",
            if ti + 1 < TopologyKind::ALL.len() { "," } else { "" }
        ));

        // Shape checks per topology. Pull a (scenario, policy) cell out
        // of the enumeration (scenario axis is slower than seeds,
        // faster than policies — but addressing by spec is robust to
        // ordering).
        let cell = |scen: &str, pol: &str| {
            result
                .points
                .iter()
                .find(|p| {
                    p.scenario.as_deref().unwrap_or("none")
                        == scenarios
                            .iter()
                            .find(|(l, _)| *l == scen)
                            .map(|(_, pl)| pl.spec())
                            .unwrap()
                            .as_str()
                        && p.policy.as_deref() == Some(pol)
                })
                .expect("grid cell present")
        };
        let clean = cell("fault-free", "none");
        let churn_none = cell("compound", "none");
        let churn_both = cell("compound", "tau=9+deadline=3");
        // churn must cost something (a dead worker's micro-batches are
        // lost)...
        assert!(
            churn_none.drop_rate > 0.0,
            "{}: compound churn must drop work",
            kind.name()
        );
        assert!(
            clean.drop_rate < churn_none.drop_rate,
            "{}: fault-free baseline out-drops churn?",
            kind.name()
        );
        // ...but the cluster must degrade, not collapse: the surviving
        // members keep reducing and useful throughput stays within the
        // same order of magnitude.
        assert!(
            churn_none.throughput > 0.3 * clean.throughput,
            "{}: churn collapsed throughput ({} vs {})",
            kind.name(),
            churn_none.throughput,
            clean.throughput
        );
        // the composed policy should not do worse than no policy under
        // the same churn (it sheds stragglers the fault plan slowed)
        assert!(
            churn_both.throughput > 0.8 * churn_none.throughput,
            "{}: policies made churn worse ({} vs {})",
            kind.name(),
            churn_both.throughput,
            churn_none.throughput
        );
        // every cell stays finite and NaN-free — the degenerate guards
        for p in &result.points {
            assert!(p.mean_iter_time.is_finite());
            assert!(!p.drop_rate.is_nan());
            assert!((0.0..=1.0).contains(&p.drop_rate));
        }
    }
    json.push_str("  ]\n}\n");

    println!("JSON_BEGIN");
    print!("{json}");
    println!("JSON_END");

    let doc = Json::parse(&json).expect("bench must emit valid JSON");
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert_eq!(topos.len(), TopologyKind::ALL.len());
    for t in topos {
        let pts = t.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 9, "3 scenarios x 3 policies");
        assert!(
            pts.iter().any(|p| p
                .get("spec")
                .and_then(Json::as_str)
                .is_some_and(|s| s.contains("rejoin+"))),
            "transient arm missing"
        );
    }
    std::fs::write("BENCH_churn.json", &json).expect("write BENCH_churn.json");
    println!(
        "\nSHAPE CHECK PASSED: {} topologies x 3 scenarios x 3 policies; \
         wrote BENCH_churn.json",
        TopologyKind::ALL.len()
    );
}
