//! Figure 5 — DropCompute improves training time under compute variance:
//! REAL LM training (through the PJRT artifacts) in the simulated-delay
//! environment; loss vs steps and loss vs virtual time.

mod common;

use common::{header, paper_noise};
use dropcompute::config::{Config, ThresholdPolicy};
use dropcompute::report::{f, pct, Table};
use dropcompute::train::Trainer;

fn main() {
    header(
        "Figure 5 — train loss vs steps and vs time (real training)",
        "DropCompute needs a few % more steps but reaches equal loss in \
         ~13% less time (N=64 in the paper; scaled-down cluster here)",
    );
    let steps = 120;
    let mut cfg = Config::default();
    cfg.train.model_size = "test".into();
    cfg.train.steps = steps;
    cfg.train.lr = 2.5e-3;
    cfg.train.log_every = 10_000;
    cfg.cluster.workers = 16;
    cfg.cluster.accumulations = 6;
    cfg.cluster.noise = paper_noise();

    let mut base_cfg = cfg.clone();
    base_cfg.dropcompute.policy = ThresholdPolicy::Off;
    let base = Trainer::new(&base_cfg).unwrap().train().unwrap();

    let mut dc_cfg = cfg.clone();
    dc_cfg.dropcompute.policy = ThresholdPolicy::Auto;
    let mut dc_tr = Trainer::new(&dc_cfg).unwrap();
    let dc = dc_tr.train().unwrap();

    let mut t = Table::new(
        "Fig 5 — loss curves",
        &["step", "base loss", "base vt(s)", "dc loss", "dc vt(s)"],
    );
    for i in (0..steps).step_by(steps / 10) {
        t.row(vec![
            i.to_string(),
            f(base.steps[i].loss, 4),
            f(base.steps[i].virtual_time, 0),
            f(dc.steps[i].loss, 4),
            f(dc.steps[i].virtual_time, 0),
        ]);
    }
    t.print();

    let target = base.final_loss();
    let hit = dc.steps.iter().find(|s| s.loss <= target);
    let mut s = Table::new("summary", &["metric", "baseline", "DropCompute"]);
    s.row(vec!["final loss".into(), f(base.final_loss(), 4), f(dc.final_loss(), 4)]);
    s.row(vec!["drop rate".into(), pct(base.mean_drop_rate()), pct(dc.mean_drop_rate())]);
    s.row(vec![
        "virtual time".into(),
        f(base.total_virtual_time(), 0),
        f(dc.total_virtual_time(), 0),
    ]);
    s.print();

    // shape: equal-loss wall time is lower with DropCompute
    match hit {
        Some(rec) => {
            let saved = 1.0 - rec.virtual_time / base.total_virtual_time();
            println!(
                "DropCompute reached baseline loss at step {} ({:+.1}% steps) \
                 in {:.1}% less time",
                rec.step,
                100.0 * (rec.step as f64 / steps as f64 - 1.0),
                100.0 * saved
            );
            assert!(saved > 0.0, "must reach equal loss in less time");
            println!("\nSHAPE CHECK PASSED");
        }
        None => {
            // still must be faster per step
            assert!(dc.total_virtual_time() < base.total_virtual_time());
            println!(
                "\nSHAPE CHECK PASSED (same-budget: dc loss {:.4} vs {:.4} \
                 in {:.1}% less time)",
                dc.final_loss(),
                target,
                100.0 * (1.0 - dc.total_virtual_time() / base.total_virtual_time())
            );
        }
    }
}
