//! Figure 9 (App. B.2.1) — train-loss convergence across drop rates:
//! REAL training of the test LM at 0% / 3% / 6% / 11% drops; the curves
//! must overlap (stochastic batch size does not hurt optimization).

mod common;

use common::{header, paper_noise};
use dropcompute::config::{Config, ThresholdPolicy};
use dropcompute::report::{f, Table};
use dropcompute::train::Trainer;

fn main() {
    header(
        "Figure 9 — loss convergence for different drop rates (real runs)",
        "curves for <=11% drop overlap with the 0% baseline",
    );
    let steps = 100;
    let rates = [0.0, 0.03, 0.06, 0.11];
    let mut logs = Vec::new();
    for &rate in &rates {
        let mut cfg = Config::default();
        cfg.train.model_size = "test".into();
        cfg.train.steps = steps;
        cfg.train.lr = 2.5e-3;
        cfg.train.log_every = 10_000;
        cfg.cluster.workers = 8;
        cfg.cluster.accumulations = 6;
        cfg.cluster.noise = paper_noise();
        cfg.dropcompute.policy = if rate == 0.0 {
            ThresholdPolicy::Off
        } else {
            ThresholdPolicy::TargetDropRate(rate)
        };
        logs.push(Trainer::new(&cfg).unwrap().train().unwrap());
    }

    let mut t = Table::new(
        "Fig 9 — train loss by step",
        &["step", "0%", "3%", "6%", "11%"],
    );
    for i in (0..steps).step_by(steps / 10) {
        t.row(vec![
            i.to_string(),
            f(logs[0].steps[i].loss, 4),
            f(logs[1].steps[i].loss, 4),
            f(logs[2].steps[i].loss, 4),
            f(logs[3].steps[i].loss, 4),
        ]);
    }
    t.print();
    for (rate, log) in rates.iter().zip(&logs) {
        println!(
            "target {:4.1}%  realized {:4.1}%  final loss {:.4}",
            rate * 100.0,
            log.mean_drop_rate() * 100.0,
            log.final_loss()
        );
    }

    // shape: all final losses within a tight band of the baseline
    let base = logs[0].final_loss();
    for (rate, log) in rates.iter().zip(&logs).skip(1) {
        let gap = (log.final_loss() - base).abs();
        assert!(
            gap < 0.15 * base.max(0.5),
            "drop {rate}: final loss {} vs baseline {base}",
            log.final_loss()
        );
    }
    println!("\nSHAPE CHECK PASSED: convergence unaffected up to 11% drops");
}
