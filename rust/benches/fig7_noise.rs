//! Figure 7 (App. B.1) — the simulated-delay environment's distributions:
//! (left) additive noise eps = min(Z/alpha, beta), Z ~ LogNormal(4, 1);
//! (right) resulting step time T_n with 12 accumulations.

mod common;

use common::{header, paper_cluster};
use dropcompute::rng::{BoundedLogNormal, Distribution, Xoshiro256pp};
use dropcompute::sim::ClusterSim;
use dropcompute::stats::{Histogram, Welford};

fn main() {
    header(
        "Figure 7 — additive noise and resulting iteration times",
        "eps has mean ~0.5 (x1.5 slowdown per accumulation) bounded at \
         5.5 (max ~6x); T_n over 12 accumulations is right-skewed",
    );

    // left: the noise itself
    let d = BoundedLogNormal::paper_default();
    let mut rng = Xoshiro256pp::seed_from_u64(71);
    let mut h = Histogram::new(0.0, 5.6, 40);
    let mut w = Welford::new();
    for _ in 0..200_000 {
        let x = d.sample(&mut rng);
        h.push(x);
        w.push(x);
    }
    println!("\nadditive noise eps:");
    println!("  sampled mean {:.3} (analytic {:.3}), max {:.2} (bound 5.5)",
             w.mean(), d.mean(), w.max());
    println!("  [0 .. 5.6] {}", h.sparkline());
    assert!((w.mean() - d.mean()).abs() < 0.01);
    assert!(w.max() <= 5.5 + 1e-9);

    // right: step time T_n with 12 accumulations under this noise
    let cfg = paper_cluster(8);
    let mut sim = ClusterSim::new(&cfg, 72);
    let trace = sim.record_trace(100);
    let mut hw = Histogram::new(4.0, 16.0, 40);
    let mut ww = Welford::new();
    for i in 0..trace.iters {
        for n in 0..trace.workers {
            let t = trace.worker_step_time(i, n);
            hw.push(t);
            ww.push(t);
        }
    }
    println!("\nstep time T_n (12 accumulations):");
    println!("  mean {:.2}s  std {:.2}s  max {:.2}s  (no-noise baseline 5.4s)",
             ww.mean(), ww.std(), ww.max());
    println!("  [4 .. 16s] {}", hw.sparkline());

    // shape: ~1.5x mean slowdown, right skew (mean > median-ish check)
    let slowdown = ww.mean() / 5.4;
    assert!((1.3..1.7).contains(&slowdown), "slowdown {slowdown}");
    assert!(ww.max() > ww.mean() + 3.0 * ww.std(), "right tail expected");
    println!("\nSHAPE CHECK PASSED: x{slowdown:.2} mean slowdown, heavy right tail");
}
