//! Figure 8 (App. B.2.1) — total batch size distribution under
//! DropCompute at drop rates ~2.5% / 5.5% / 11.5%.

mod common;

use common::{header, paper_cluster};
use dropcompute::analysis::threshold_for_drop_rate;
use dropcompute::report::pct;
use dropcompute::sim::ClusterSim;
use dropcompute::stats::{Histogram, Welford};

fn main() {
    header(
        "Figure 8 — batch size distribution vs drop rate",
        "batch size concentrates just below the maximum; the mass shifts \
         left and widens as the drop rate grows",
    );
    let cfg = paper_cluster(64);
    let full = (cfg.workers * cfg.accumulations) as f64;

    let mut cal = ClusterSim::new(&cfg, 81);
    let trace = cal.record_trace(40);

    for target in [0.025, 0.055, 0.115] {
        let tau = threshold_for_drop_rate(&trace, target);
        let mut sim = ClusterSim::new(&cfg, 82);
        let mut h = Histogram::new(0.75 * full, full + 1.0, 36);
        let mut w = Welford::new();
        for _ in 0..400 {
            let out = sim.step(Some(tau));
            let b = out.total_completed() as f64;
            h.push(b);
            w.push(b);
        }
        println!(
            "\ntarget drop {} (tau {:.2}s): batch mean {:.1}/{} ({}), std {:.1}",
            pct(target),
            tau,
            w.mean(),
            full,
            pct(1.0 - w.mean() / full),
            w.std()
        );
        println!("  [{:.0} .. {:.0}] {}", 0.75 * full, full, h.sparkline());
        assert!(
            ((1.0 - w.mean() / full) - target).abs() < 0.03,
            "realized drop should match target"
        );
        assert!(w.max() <= full, "cannot exceed the maximal batch");
    }
    println!("\nSHAPE CHECK PASSED: realized drop tracks target; mass below b_max");
}
