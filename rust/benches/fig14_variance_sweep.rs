//! Figure 14 (App. C.3) — DropCompute increases robustness to the noise
//! *variance*: lognormal noise with fixed mean 0.225 and variance swept
//! 0.05 -> 0.3 (the paper's E[T]/E[T_i] goes 1.496 -> 3.4).

mod common;

use common::header;
use dropcompute::config::{ClusterConfig, NoiseKind};
use dropcompute::coordinator::ScaleRun;
use dropcompute::report::{f, Table};
use dropcompute::sim::ClusterSim;

fn cluster(var: f64) -> ClusterConfig {
    ClusterConfig {
        workers: 1,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.01,
        comm_latency: 0.5,
        noise: NoiseKind::LogNormal { mean: 0.225, var },
        ..Default::default()
    }
}

fn ratio(cfg: &ClusterConfig, workers: usize) -> f64 {
    let mut single = cfg.clone();
    single.workers = 1;
    let mut s1 = ClusterSim::new(&single, 141);
    let t1: f64 =
        (0..150).map(|_| s1.step(None).compute_time).sum::<f64>() / 150.0;
    let mut many = cfg.clone();
    many.workers = workers;
    let mut sn = ClusterSim::new(&many, 142);
    let tn: f64 =
        (0..150).map(|_| sn.step(None).compute_time).sum::<f64>() / 150.0;
    tn / t1
}

fn main() {
    header(
        "Figure 14 — robustness to noise variance (lognormal, mean 0.225)",
        "E[T]/E[T_i] grows with Var(eps); baseline efficiency collapses \
         while DropCompute holds on to most of it",
    );
    let vars = [0.05, 0.1, 0.15, 0.2, 0.25, 0.3];
    let mut t = Table::new(
        "Fig 14 — variance sweep at N=200",
        &["Var(eps)", "E[T]/E[T_i]", "base eff", "dc eff", "speedup", "drop"],
    );
    // Independent variance points — fan them over the sweep engine.
    let measured = dropcompute::sweep::run_indexed(
        vars.len(),
        0,
        Some("fig14"),
        move |i| {
            let v = vars[i];
            let cfg = cluster(v);
            let r = ratio(&cfg, 64);
            let run = ScaleRun {
                base: cfg,
                calibration_iters: 12,
                measure_iters: 50,
                grid: 128,
                seed: 143,
                ..ScaleRun::default()
            };
            (v, r, run.point(200))
        },
    );
    let mut rows = Vec::new();
    for (v, r, p) in &measured {
        let (v, r, p) = (*v, *r, p);
        t.row(vec![
            f(v, 2),
            f(r, 3),
            f(p.baseline_throughput / p.linear_throughput, 3),
            f(p.dropcompute_throughput / p.linear_throughput, 3),
            f(p.dropcompute_throughput / p.baseline_throughput, 3),
            f(p.drop_rate, 3),
        ]);
        rows.push((v, r, p.baseline_throughput / p.linear_throughput,
                   p.dropcompute_throughput / p.baseline_throughput));
    }
    t.print();

    // shape: ratio increases with variance; baseline efficiency decreases;
    // DropCompute's speedup increases.
    for w in rows.windows(2) {
        assert!(w[1].1 > w[0].1 * 0.98, "ratio should grow: {rows:?}");
    }
    assert!(rows.last().unwrap().2 < rows[0].2, "baseline eff should fall");
    assert!(
        rows.last().unwrap().3 > rows[0].3,
        "speedup should grow with variance"
    );
    println!(
        "\nSHAPE CHECK PASSED: E[T]/E[T_i] {:.2} -> {:.2}, speedup x{:.3} -> x{:.3}",
        rows[0].1,
        rows.last().unwrap().1,
        rows[0].3,
        rows.last().unwrap().3
    );
}
