//! Table 1 — maintaining final quality under DropCompute (real runs):
//! (a) drop rates 0 / ~3 / ~6 / ~11 % without compensation;
//! (b) compensation methods at ~10-11% drops.
//! The SQuAD-F1 metric is substituted by held-out eval loss
//! (DESIGN.md §Substitutions); 3 seeds each, mean ± std.

mod common;

use common::{header, paper_noise};
use dropcompute::config::{Compensation, Config, ThresholdPolicy};
use dropcompute::report::{f, pct, Table};
use dropcompute::train::Trainer;

fn run(rate: f64, comp: Compensation, seed: u64) -> (f64, f64) {
    let mut cfg = Config::default();
    cfg.train.model_size = "test".into();
    cfg.train.steps = 90;
    cfg.train.lr = 2.5e-3;
    cfg.train.seed = seed;
    cfg.train.log_every = 10_000;
    cfg.train.eval_batches = 8;
    cfg.cluster.workers = 8;
    cfg.cluster.accumulations = 6;
    cfg.cluster.noise = paper_noise();
    cfg.dropcompute.policy = if rate == 0.0 {
        ThresholdPolicy::Off
    } else {
        ThresholdPolicy::TargetDropRate(rate)
    };
    cfg.dropcompute.compensation = comp;
    let mut t = Trainer::new(&cfg).unwrap();
    let log = t.train().unwrap();
    (log.summary["final_eval_loss"], log.mean_drop_rate())
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

fn main() {
    header(
        "Table 1 — final quality vs drop rate and compensation (3 seeds)",
        "(a) <=11% drops leave quality unchanged; (b) all compensation \
         methods restore/keep quality at ~10% drops",
    );

    // (a) drop-rate sweep, no compensation
    let mut ta = Table::new(
        "Table 1a — eval loss vs drop rate (lower is better)",
        &["target drop", "realized", "eval loss", "±"],
    );
    let mut base_mean = 0.0;
    for &rate in &[0.0, 0.03, 0.06, 0.11] {
        let runs: Vec<(f64, f64)> =
            (0..3).map(|s| run(rate, Compensation::None, s)).collect();
        let (m, sd) = mean_std(&runs.iter().map(|r| r.0).collect::<Vec<_>>());
        let realized =
            runs.iter().map(|r| r.1).sum::<f64>() / runs.len() as f64;
        if rate == 0.0 {
            base_mean = m;
        }
        ta.row(vec![pct(rate), pct(realized), f(m, 4), f(sd, 4)]);
        assert!(
            (m - base_mean).abs() < 0.12 * base_mean.max(1.0),
            "drop {rate}: {m} vs baseline {base_mean}"
        );
    }
    ta.print();

    // (b) compensation methods at ~10-11% drops
    let mut tb = Table::new(
        "Table 1b — compensation methods at ~10% drops",
        &["method", "eval loss", "±"],
    );
    for (name, comp) in [
        ("none", Compensation::None),
        ("extra steps", Compensation::ExtraSteps),
        ("increased batch", Compensation::IncreasedBatch),
        ("re-computation", Compensation::Resample),
    ] {
        let runs: Vec<f64> =
            (0..3).map(|s| run(0.105, comp, 10 + s).0).collect();
        let (m, sd) = mean_std(&runs);
        tb.row(vec![name.into(), f(m, 4), f(sd, 4)]);
        assert!(
            m < base_mean * 1.12,
            "{name}: {m} should stay near baseline {base_mean}"
        );
    }
    tb.print();
    println!("\nSHAPE CHECK PASSED: quality preserved at <=11% drops, all \
              compensation methods competitive (baseline eval {base_mean:.4})");
}
