//! §Trace — record / replay throughput per topology.
//!
//! Records one golden trace per topology from a live seeded run (under
//! a per-phase drop policy, so the drop paths are on the clock), proves
//! replay == recorded bitwise on both timing paths, then measures:
//!
//! * `record_rate`  — live steps/s with the [`TraceWriter`] tap on;
//! * `replay_rate`  — replayed steps/s, event-queue oracle (before)
//!   vs compiled pass (after).
//!
//! Emits `BENCH_trace_replay.json` (same machine-readable shape as
//! `BENCH_perf.json`; the CI-tracked smoke entry lives in
//! `perf_hotpaths --smoke` as `trace_replay_rate`).

mod common;

use std::time::Instant;

use common::{header, paper_cluster};
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, Table};
use dropcompute::runtime::json::Json;
use dropcompute::sim::{ClusterSim, StepOutcome};
use dropcompute::topology::TopologyKind;

fn main() {
    header(
        "§Trace — record/replay throughput",
        "replay must reproduce recorded runs bitwise at simulator speed",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let steps = if smoke { 60 } else { 400 };
    let workers = 32;

    let mut table = Table::new(
        "trace replay",
        &["topology", "metric", "value"],
    );
    let mut entries = String::new();
    let mut first = true;

    for kind in TopologyKind::ALL {
        let mut cfg = paper_cluster(workers);
        cfg.topology = Some(kind);
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        cfg.stragglers = dropcompute::config::StragglerKind::Uniform {
            p: 0.2,
            delay: 6.0,
        };
        let policy = DropPolicy::parse("tau=9+phase-deadline=2/0.5/0.5")
            .expect("valid spec");

        // --- record (writer tap on) ---------------------------------
        let mut live = ClusterSim::new(&cfg, 0x7AC5).with_policy(policy);
        live.start_recording();
        let mut out = StepOutcome::default();
        let t0 = Instant::now();
        for _ in 0..steps {
            live.step_installed_into(&mut out);
        }
        let record_secs = t0.elapsed().as_secs_f64();
        let trace = live.finish_recording().expect("consistent recording");
        assert_eq!(trace.len(), steps);

        // --- conformance: replay == recorded, both paths ------------
        for reference in [false, true] {
            let mut replay =
                ClusterSim::from_trace(&trace).expect("valid trace");
            if reference {
                replay = replay.with_reference_timing();
            }
            for (i, rec) in trace.outcomes.iter().enumerate() {
                replay.replay_into(&mut out).expect("within length");
                assert!(
                    rec.matches(&out),
                    "{} step {i} (reference={reference}): replay must \
                     reproduce the recorded outcome bitwise",
                    kind.name()
                );
            }
        }

        // --- replay rate: oracle (before) vs compiled (after) -------
        let mut timed = |reference: bool| -> f64 {
            let mut sim = ClusterSim::from_trace(&trace).expect("valid");
            if reference {
                sim = sim.with_reference_timing();
            }
            let t0 = Instant::now();
            while sim.replay_remaining() > 0 {
                sim.replay_into(&mut out).expect("within length");
            }
            t0.elapsed().as_secs_f64()
        };
        let t_oracle = timed(true);
        let t_compiled = timed(false);

        let record_rate = steps as f64 / record_secs;
        let rate_oracle = steps as f64 / t_oracle;
        let rate_compiled = steps as f64 / t_compiled;
        table.row(vec![
            kind.name().into(),
            "record steps/s".into(),
            f(record_rate, 0),
        ]);
        table.row(vec![
            kind.name().into(),
            "replay steps/s oracle->compiled".into(),
            format!(
                "{} -> {} (x{})",
                f(rate_oracle, 0),
                f(rate_compiled, 0),
                f(rate_compiled / rate_oracle, 2)
            ),
        ]);
        if !first {
            entries.push_str(",\n");
        }
        first = false;
        entries.push_str(&format!(
            "    {{\"topology\": \"{}\", \"record_rate\": {record_rate:?}, \
             \"replay_rate_oracle\": {rate_oracle:?}, \
             \"replay_rate_compiled\": {rate_compiled:?}}}",
            kind.name()
        ));
    }
    table.print();

    let json = format!(
        "{{\n  \"bench\": \"trace_replay\",\n  \"workers\": {workers},\n  \
         \"steps\": {steps},\n  \"entries\": [\n{entries}\n  ]\n}}\n"
    );
    Json::parse(&json).expect("bench must emit valid JSON");
    std::fs::write("BENCH_trace_replay.json", &json)
        .expect("write BENCH_trace_replay.json");
    println!("wrote BENCH_trace_replay.json");
}
