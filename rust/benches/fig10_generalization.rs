//! Figures 10 & 11 (App. B.2.2) — generalization vs simulated drop rate
//! for two optimizer regimes (SGD+momentum / LARS), and the learning-rate
//! corrections. ResNet-50/ImageNet is substituted by the synthetic
//! classification task (DESIGN.md §Substitutions): the mechanism under
//! test — whole-worker gradient drops w.p. p — is identical.

mod common;

use common::header;
use dropcompute::config::OptimizerKind;
use dropcompute::data::ClassificationTask;
use dropcompute::report::{f, pct, Table};
use dropcompute::train::{train_classifier, ClassifierConfig, LrCorrection};

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

fn sweep(
    task: &ClassificationTask,
    optimizer: OptimizerKind,
    lr: f64,
    correction: LrCorrection,
    rates: &[f64],
) -> Vec<(f64, f64)> {
    rates
        .iter()
        .map(|&p| {
            let accs: Vec<f64> = (0..5)
                .map(|seed| {
                    let cfg = ClassifierConfig {
                        p_drop: p,
                        optimizer,
                        lr,
                        correction,
                        seed,
                        steps: 10,
                        ..Default::default()
                    };
                    train_classifier(task, &cfg).test_accuracy
                })
                .collect();
            mean_std(&accs)
        })
        .collect()
}

fn main() {
    header(
        "Figures 10/11 — accuracy vs simulated drop rate (5 seeds each)",
        "<=10% drops: negligible accuracy change under both SGD and LARS \
         regimes, with or without LR correction",
    );
    let task = ClassificationTask::new(10, 24, 1.5, 7);
    let rates = [0.0, 0.02, 0.05, 0.10, 0.20, 0.40];

    let mut t = Table::new(
        "Fig 10 — test accuracy vs drop rate",
        &["drop", "SGD acc", "±", "LARS acc", "±"],
    );
    let sgd = sweep(&task, OptimizerKind::Momentum, 0.3, LrCorrection::None, &rates);
    let lars = sweep(&task, OptimizerKind::Lars, 0.3, LrCorrection::None, &rates);
    for ((&r, s), l) in rates.iter().zip(&sgd).zip(&lars) {
        t.row(vec![
            pct(r),
            f(s.0, 4),
            f(s.1, 4),
            f(l.0, 4),
            f(l.1, 4),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Fig 11 — LR corrections at 10% drops (SGD)",
        &["correction", "accuracy", "±"],
    );
    for (name, corr) in [
        ("none", LrCorrection::None),
        ("constant (1-p)", LrCorrection::Constant),
        ("stochastic", LrCorrection::Stochastic),
    ] {
        let pt = sweep(&task, OptimizerKind::Momentum, 0.3, corr, &[0.10])[0];
        t2.row(vec![name.into(), f(pt.0, 4), f(pt.1, 4)]);
    }
    t2.print();

    // shape: <=10% drop -> accuracy within noise of baseline, both regimes
    for (label, runs) in [("SGD", &sgd), ("LARS", &lars)] {
        let base = runs[0].0;
        for (i, &r) in rates.iter().enumerate() {
            if r <= 0.10 {
                assert!(
                    runs[i].0 > base - 0.03,
                    "{label} at {r}: {} vs base {base}",
                    runs[i].0
                );
            }
        }
    }
    println!("\nSHAPE CHECK PASSED: <=10% drops leave accuracy unchanged");
}
