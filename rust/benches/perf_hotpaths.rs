//! §Perf — hot-path microbenchmarks for the L3 coordinator and runtime:
//! ring AllReduce bandwidth, event-queue throughput, simulator step
//! rate (compiled vs event-queue schedule timing), DropComm drop-path
//! step rate (cached survivor schedules vs per-drop rebuild), policy
//! dispatch (unified DropPolicy surface vs direct legacy calls),
//! observer overhead (NoopObserver step path vs a live ObsRecorder),
//! trace replay rate (recorded trace through the compiled pass vs the
//! event-queue oracle, conformance-gated), batched noise sampling (enum
//! vs boxed dispatch), multi-replica batched stepping (SoA lockstep vs
//! per-replica scalar, parity-gated) with the 4-wide phase-scan
//! reduction, parallel sweep scaling, Algorithm-2 sweep cost, PJRT
//! grad-step + upload overhead.
//!
//! Besides the human-readable table, emits `BENCH_perf.json` — one
//! entry per path with `metric`, `value` and (where the path has a
//! before/after comparison) both arms — so the perf trajectory is
//! machine-trackable across PRs.
//!
//! `--smoke` shrinks every section (fewer reps, smaller buffers) for
//! the per-PR CI run, which uploads the JSON as an artifact; full runs
//! are for measured numbers in the README.

mod common;

use std::time::Instant;

use common::{header, paper_cluster};
use dropcompute::analysis::choose_threshold;
use dropcompute::collective::{ring_all_reduce, ring_all_reduce_naive, Communicator};
use dropcompute::config::{NoiseKind, StragglerKind};
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, Table};
use dropcompute::rng::{Distribution, Xoshiro256pp};
use dropcompute::runtime::json::Json;
use dropcompute::runtime::ModelRuntime;
use dropcompute::sim::{
    build_noise, scan_max4, ClusterSim, EventQueue, NoiseSampler,
    ReplicaBatch, StepOutcome,
};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;
use dropcompute::train::ParamStore;

fn bench<R>(reps: usize, mut body: impl FnMut() -> R) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(body());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Regression gate on a before/after timing pair: the "after" arm must
/// win outright (panic in full runs, warn in --smoke where shared-runner
/// noise makes wall-clock ratios unreliable — there the bitwise asserts
/// are what gate), and speedups below `warn_ratio` print an advisory.
/// The real acceptance targets are judged from BENCH_perf.json.
fn gate(label: &str, t_before: f64, t_after: f64, warn_ratio: f64, smoke: bool) {
    let ratio = t_before / t_after;
    if ratio <= 1.0 {
        let msg = format!(
            "{label}: fast path should beat the reference \
             ({:.0} vs {:.0} steps/s)",
            1.0 / t_after,
            1.0 / t_before,
        );
        if smoke {
            println!("WARNING (smoke): {msg}");
        } else {
            panic!("{msg}");
        }
    } else if ratio < warn_ratio {
        println!("WARNING: {label} speedup only x{ratio:.2} (machine load?)");
    }
}

/// One machine-readable measurement: `before`/`after` are both set when
/// the path is a before/after comparison (then `value == after`).
struct Entry {
    path: String,
    metric: String,
    value: f64,
    before: Option<f64>,
    after: Option<f64>,
}

struct Perf {
    table: Table,
    entries: Vec<Entry>,
}

impl Perf {
    fn new() -> Self {
        Self {
            table: Table::new("hot paths", &["path", "metric", "value"]),
            entries: Vec::new(),
        }
    }

    fn record(&mut self, path: &str, metric: &str, value: f64, shown: String) {
        self.table.row(vec![path.into(), metric.into(), shown]);
        self.entries.push(Entry {
            path: path.into(),
            metric: metric.into(),
            value,
            before: None,
            after: None,
        });
    }

    fn record_ba(
        &mut self,
        path: &str,
        metric: &str,
        before: f64,
        after: f64,
    ) {
        self.table.row(vec![
            path.into(),
            format!("{metric} before->after"),
            format!("{} -> {} (x{})", f(before, 2), f(after, 2), f(after / before, 2)),
        ]);
        self.entries.push(Entry {
            path: path.into(),
            metric: metric.into(),
            value: after,
            before: Some(before),
            after: Some(after),
        });
    }

    fn to_json(&self) -> String {
        let mut s =
            String::from("{\n  \"bench\": \"perf_hotpaths\",\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"path\": \"{}\", \"metric\": \"{}\", \"value\": {:?}",
                e.path, e.metric, e.value
            ));
            if let (Some(b), Some(a)) = (e.before, e.after) {
                s.push_str(&format!(", \"before\": {b:?}, \"after\": {a:?}"));
            }
            s.push_str(&format!(
                "}}{}\n",
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn main() {
    header("§Perf — L3/runtime hot paths", "coordinator must not be the bottleneck");
    // CI smoke mode: same sections, smaller workloads.
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("(smoke mode: reduced reps/sizes)");
    }
    let mut perf = Perf::new();

    // ---- ring AllReduce on gradient-sized buffers -------------------
    // Threads are pre-spawned and iterate in-thread so the measurement
    // excludes spawn cost; before = naive per-chunk allocation,
    // after = buffer-recycling implementation.
    fn measure_ring(n: usize, len: usize, reps: usize, naive: bool) -> f64 {
        let comms = Communicator::ring(n);
        let t0 = Instant::now();
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    for _ in 0..reps {
                        if naive {
                            ring_all_reduce_naive(&c, &mut buf);
                        } else {
                            ring_all_reduce(&c, &mut buf);
                        }
                    }
                    buf[0]
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        t0.elapsed().as_secs_f64() / reps as f64
    }
    let ring_cases: &[(usize, usize)] = if smoke {
        &[(4usize, 1_000_000usize)]
    } else {
        &[(4, 1_000_000), (8, 1_000_000), (8, 8_000_000)]
    };
    for &(n, len) in ring_cases {
        let reps = if smoke { 3 } else { 8 };
        let before = measure_ring(n, len, reps, true);
        let after = measure_ring(n, len, reps, false);
        // algorithmic bytes moved per worker: 2(N-1)/N * 4*len
        let alg = 2.0 * (n - 1) as f64 / n as f64 * 4.0 * len as f64;
        perf.record_ba(
            &format!("ring_all_reduce_n{n}_len{}M", len / 1_000_000),
            "GB/s/worker",
            alg / before / 1e9,
            alg / after / 1e9,
        );
    }

    // ---- event queue -------------------------------------------------
    let per = bench(20, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at((i % 97) as f64, i);
        }
        while q.pop().is_some() {}
        q.processed()
    });
    perf.record(
        "event_queue_10k",
        "Mops/s",
        20_000.0 / per / 1e6,
        f(20_000.0 / per / 1e6, 2),
    );

    // ---- simulator step rate: compiled vs event-queue timing ---------
    // The acceptance path of the perf PR: at N=64 on a schedule-driven
    // comm model, the compiled heapless pass vs the per-phase event
    // queue (both bitwise identical in output).
    for (label, kind) in [
        ("ring", TopologyKind::Ring),
        ("torus", TopologyKind::Torus { rows: 0 }),
    ] {
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(kind);
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;

        // sanity: the two arms agree bitwise before we time them
        let mut a = ClusterSim::new(&cfg, 7);
        let mut b = ClusterSim::new(&cfg, 7).with_reference_timing();
        for _ in 0..3 {
            assert_eq!(
                a.step(Some(9.0)).iter_time.to_bits(),
                b.step(Some(9.0)).iter_time.to_bits(),
                "compiled and reference timing must agree ({label})"
            );
        }

        let reps = if smoke { 15 } else { 60 };
        let mut slow = ClusterSim::new(&cfg, 7).with_reference_timing();
        let t_before = bench(reps, || slow.step(Some(9.0)).iter_time);
        let mut fast = ClusterSim::new(&cfg, 7);
        let mut out = StepOutcome::default();
        let t_after = bench(reps, || {
            fast.step_into(Some(9.0), &mut out);
            out.iter_time
        });
        perf.record_ba(
            &format!("sim_step_rate_{label}_n64"),
            "steps/s",
            1.0 / t_before,
            1.0 / t_after,
        );
        gate(
            &format!("sim_step_rate_{label}"),
            t_before,
            t_after,
            5.0,
            smoke,
        );
    }

    // ---- DropComm drop-path step rate: survivor-schedule cache -------
    // Drop-heavy regime (every worker misses the membership deadline
    // with high probability, so essentially every step takes the
    // exclusion branch): before = event-queue bounded_wait_completion,
    // which allocates a mask + compacted arrivals and rebuilds the
    // k-survivor schedule per drop step; after = the per-k compiled
    // SurvivorScheduleCache (allocation-free after warmup). The
    // acceptance bar for this path is cached >= 3x rebuild at N=64
    // torus, judged from the recorded numbers.
    {
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(TopologyKind::Torus { rows: 0 });
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        cfg.stragglers = StragglerKind::Uniform { p: 0.25, delay: 6.0 };
        cfg.comm_drop_deadline = 2.0;

        // sanity: both arms agree bitwise, and the config is actually
        // drop-heavy
        let mut a = ClusterSim::new(&cfg, 9);
        let mut b = ClusterSim::new(&cfg, 9).with_reference_timing();
        let mut drop_steps = 0usize;
        for _ in 0..10 {
            let x = a.step(None);
            let y = b.step(None);
            assert_eq!(
                x.iter_time.to_bits(),
                y.iter_time.to_bits(),
                "cached survivor path must equal the event-queue oracle"
            );
            if x.total_completed() < 64 * cfg.accumulations {
                drop_steps += 1;
            }
        }
        assert!(drop_steps >= 9, "drop-heavy config: {drop_steps}/10 dropped");

        let reps = if smoke { 15 } else { 60 };
        let mut slow = ClusterSim::new(&cfg, 9).with_reference_timing();
        let t_before = bench(reps, || slow.step(None).iter_time);
        let mut fast = ClusterSim::new(&cfg, 9);
        let mut out = StepOutcome::default();
        let t_after = bench(reps, || {
            fast.step_into(None, &mut out);
            out.iter_time
        });
        perf.record_ba(
            "dropcomm_step_rate",
            "steps/s (torus n64, drop-heavy)",
            1.0 / t_before,
            1.0 / t_after,
        );
        gate("dropcomm_step_rate", t_before, t_after, 3.0, smoke);
    }

    // ---- policy dispatch: unified DropPolicy vs direct legacy calls --
    // The API-redesign regression gate: stepping through the installed
    // DropPolicy (enum resolution paid at install, equality check per
    // step_with call) must cost the same as the direct
    // step_into(Some(tau)) it replaced. before = legacy direct call,
    // after = policy-driven step; parity (not speedup) is the bar.
    {
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(TopologyKind::Torus { rows: 0 });
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        let policy = DropPolicy::compute_tau(9.0)
            .and(DropPolicy::comm_deadline(2.0));

        // sanity: the two surfaces agree bitwise before timing
        let mut a = ClusterSim::new(&cfg, 13).with_comm_drop(Some(2.0));
        let mut b = ClusterSim::new(&cfg, 13);
        for _ in 0..3 {
            assert_eq!(
                a.step(Some(9.0)).iter_time.to_bits(),
                b.step_with(&policy).iter_time.to_bits(),
                "policy-driven step must equal the direct legacy call"
            );
        }

        let reps = if smoke { 15 } else { 60 };
        let mut direct = ClusterSim::new(&cfg, 13).with_comm_drop(Some(2.0));
        let mut out = StepOutcome::default();
        let t_before = bench(reps, || {
            direct.step_into(Some(9.0), &mut out);
            out.iter_time
        });
        let mut unified = ClusterSim::new(&cfg, 13);
        let t_after = bench(reps, || {
            unified.step_with_into(&policy, &mut out);
            out.iter_time
        });
        perf.record_ba(
            "policy_dispatch_rate",
            "steps/s (tau=9 + deadline=2, torus n64)",
            1.0 / t_before,
            1.0 / t_after,
        );
        let overhead = t_after / t_before;
        if overhead > 1.15 {
            let msg = format!(
                "policy_dispatch_rate: unified surface x{overhead:.2} \
                 slower than the direct calls it replaced"
            );
            if smoke {
                println!("WARNING (smoke): {msg}");
            } else {
                panic!("{msg}");
            }
        }
    }

    // ---- observer overhead: NoopObserver vs live ObsRecorder ---------
    // The observability PR's acceptance pair. before = observer
    // disabled (the public step_into, which monomorphizes through
    // NoopObserver — the hooks must compile to nothing, so this arm is
    // the one tracked against pre-obs step rates in BENCH_perf.json);
    // after = a live ObsRecorder attached (histograms + attribution
    // fed every step). The recorder is allocation-free after warmup,
    // so the on-arm should stay within a few percent of off.
    {
        use dropcompute::obs::{NoopObserver, ObsRecorder};
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(TopologyKind::Torus { rows: 0 });
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        cfg.stragglers = StragglerKind::Uniform { p: 0.2, delay: 6.0 };
        cfg.comm_drop_deadline = 2.0;

        // sanity: attaching an observer must not perturb the outcome
        let mut a = ClusterSim::new(&cfg, 17);
        let mut b = ClusterSim::new(&cfg, 17);
        let mut out_a = StepOutcome::default();
        let mut out_b = StepOutcome::default();
        let mut rec = ObsRecorder::new(64);
        for i in 0..5 {
            a.step_into(Some(9.0), &mut out_a);
            b.step_observed(Some(9.0), &mut out_b, &mut rec);
            assert_eq!(
                out_a.iter_time.to_bits(),
                out_b.iter_time.to_bits(),
                "observer must not perturb the step (iter {i})"
            );
            assert_eq!(out_a.completed, out_b.completed, "iter {i}");
        }

        let reps = if smoke { 15 } else { 60 };
        let mut off = ClusterSim::new(&cfg, 17);
        let mut out = StepOutcome::default();
        let mut noop = NoopObserver;
        let t_off = bench(reps, || {
            off.step_observed(Some(9.0), &mut out, &mut noop);
            out.iter_time
        });
        let mut on = ClusterSim::new(&cfg, 17);
        let mut rec = ObsRecorder::new(64);
        let t_on = bench(reps, || {
            on.step_observed(Some(9.0), &mut out, &mut rec);
            out.iter_time
        });
        perf.record_ba(
            "obs_overhead",
            "steps/s (observer off -> on, torus n64)",
            1.0 / t_off,
            1.0 / t_on,
        );
        let overhead = t_on / t_off;
        if overhead > 1.25 {
            let msg = format!(
                "obs_overhead: live recorder x{overhead:.2} slower than \
                 the noop path"
            );
            if smoke {
                println!("WARNING (smoke): {msg}");
            } else {
                panic!("{msg}");
            }
        }
    }

    // ---- trace replay rate: recorded trace through both timing paths -
    // The trace subsystem's hot path: replaying a recorded run (the
    // budget-fit evaluator's inner loop) must run at simulator speed.
    // before = event-queue oracle replay, after = compiled replay; the
    // sanity gate is the conformance contract itself (replay ==
    // recorded outcomes, bitwise, on both arms).
    {
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(TopologyKind::Torus { rows: 0 });
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        cfg.stragglers = StragglerKind::Uniform { p: 0.2, delay: 6.0 };
        let policy = DropPolicy::parse("tau=9+phase-deadline=2/0.5/0.5")
            .expect("valid spec");
        let steps = if smoke { 20 } else { 120 };
        let mut live = ClusterSim::new(&cfg, 0x7A11).with_policy(policy);
        live.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..steps {
            live.step_installed_into(&mut out);
        }
        let trace = live.finish_recording().expect("consistent recording");
        // conformance sanity on both arms before timing
        for reference in [false, true] {
            let mut sim =
                ClusterSim::from_trace(&trace).expect("valid trace");
            if reference {
                sim = sim.with_reference_timing();
            }
            for (i, rec) in trace.outcomes.iter().enumerate() {
                sim.replay_into(&mut out).expect("within length");
                assert!(
                    rec.matches(&out),
                    "replay must reproduce the recorded outcome bitwise \
                     (step {i}, reference={reference})"
                );
            }
        }
        let mut timed = |reference: bool| -> f64 {
            let mut sim =
                ClusterSim::from_trace(&trace).expect("valid trace");
            if reference {
                sim = sim.with_reference_timing();
            }
            let t0 = Instant::now();
            while sim.replay_remaining() > 0 {
                sim.replay_into(&mut out).expect("within length");
            }
            t0.elapsed().as_secs_f64() / steps as f64
        };
        let t_before = timed(true);
        let t_after = timed(false);
        perf.record_ba(
            "trace_replay_rate",
            "steps/s (torus n64, recorded drop-heavy trace)",
            1.0 / t_before,
            1.0 / t_after,
        );
        gate("trace_replay_rate", t_before, t_after, 2.0, smoke);
    }

    // ---- batched noise sampling: enum vs boxed dispatch --------------
    // The innermost simulation loop draws one noise sample per
    // micro-batch. before = Box<dyn Distribution> (indirect call per
    // draw), after = the closed NoiseSampler enum's batched fill
    // (dispatch hoisted out of the loop, inner sampler inlined).
    {
        let kind = common::paper_noise();
        let boxed = build_noise(&kind).expect("paper noise is non-None");
        let sampler = NoiseSampler::from_kind(&kind);
        let len = if smoke { 4096 } else { 16384 };
        let mut buf = vec![0.0f64; len];
        let mut r1 = Xoshiro256pp::seed_from_u64(11);
        let mut r2 = Xoshiro256pp::seed_from_u64(11);
        // draw-for-draw identical before timing
        sampler.fill(&mut buf, &mut r2);
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(
                x.to_bits(),
                boxed.sample(&mut r1).to_bits(),
                "enum and boxed samplers must agree at draw {i}"
            );
        }
        let reps = if smoke { 40 } else { 200 };
        let t_before = bench(reps, || {
            for s in buf.iter_mut() {
                *s = boxed.sample(&mut r1);
            }
            buf[0]
        });
        let t_after = bench(reps, || {
            sampler.fill(&mut buf, &mut r2);
            buf[0]
        });
        perf.record_ba(
            "noise_fill_rate",
            "Msamples/s (paper lognormal)",
            len as f64 / t_before / 1e6,
            len as f64 / t_after / 1e6,
        );
        // NoiseKind coverage smoke: every family fills without dispatch
        // through a vtable (value sanity only; the bitwise property
        // tests live in tests/perf_equivalence.rs)
        for fam in [
            NoiseKind::LogNormal { mean: 0.225, var: 0.05 },
            NoiseKind::Exponential { mean: 0.225 },
            NoiseKind::Gamma { mean: 0.225, var: 0.05 },
        ] {
            let s = NoiseSampler::from_kind(&fam);
            s.fill(&mut buf[..256], &mut r2);
            assert!(buf[..256].iter().all(|v| v.is_finite()), "{fam:?}");
        }
    }

    // ---- multi-replica batched stepping: SoA lockstep vs solo scalar -
    // S replicas (same topology/policy, different seeds) step through
    // ONE walk of the compiled phase schedule instead of S; at N=128
    // the schedule stream (offsets/srcs/dsts/hops) is ~0.5 MB per
    // scalar step, so serving all lanes per walk is the win. before =
    // S solo scalar sims stepped sequentially (per-replica-step time),
    // after = ReplicaBatch::step_installed_into / S. The parity loop
    // ahead of the timing is the CI batched-vs-scalar sanity gate: the
    // scalar pass stays the oracle.
    {
        let lanes = 16usize;
        let mut cfg = paper_cluster(128);
        cfg.topology = Some(TopologyKind::Ring);
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;
        cfg.accumulations = 2; // cheap noise: the schedule walk dominates
        let policy = DropPolicy::compute_tau(9.0);
        let mk_sims = || -> Vec<ClusterSim> {
            (0..lanes as u64)
                .map(|r| {
                    ClusterSim::new(&cfg, 0xBA7C + r)
                        .with_policy(policy.clone())
                })
                .collect()
        };

        // parity gate: every lane bitwise equal to its solo run
        let mut solo = mk_sims();
        let mut batch = ReplicaBatch::from_sims(mk_sims());
        let mut outs = vec![StepOutcome::default(); lanes];
        let mut out = StepOutcome::default();
        for i in 0..5 {
            batch.step_installed_into(&mut outs);
            for (r, s) in solo.iter_mut().enumerate() {
                s.step_installed_into(&mut out);
                assert_eq!(
                    out.iter_time.to_bits(),
                    outs[r].iter_time.to_bits(),
                    "batched lane {r} must equal its solo run (step {i})"
                );
                assert_eq!(out.completed, outs[r].completed, "lane {r}");
            }
        }

        let reps = if smoke { 8 } else { 30 };
        let mut solo = mk_sims();
        let t_before = bench(reps, || {
            for s in solo.iter_mut() {
                s.step_installed_into(&mut out);
            }
            out.iter_time
        }) / lanes as f64;
        let mut batch = ReplicaBatch::from_sims(mk_sims());
        let t_after = bench(reps, || {
            batch.step_installed_into(&mut outs);
            outs[0].iter_time
        }) / lanes as f64;
        perf.record_ba(
            "batched_step_rate",
            &format!("replica-steps/s (ring n128, S={lanes})"),
            1.0 / t_before,
            1.0 / t_after,
        );
        gate("batched_step_rate", t_before, t_after, 4.0, smoke);
    }

    // ---- SIMD phase scan: chunked 4-wide max vs sequential fold ------
    // The batched pass's per-phase reduction. scan_max4 keeps four
    // independent accumulators (breaking the fold's serial dependence)
    // with an order-fixed combine, so it is bitwise equal to the
    // sequential fold on every readiness buffer the simulator can
    // produce — asserted here on random + edge-case inputs, then timed.
    {
        let len = if smoke { 4096 } else { 16384 };
        let mut rng = Xoshiro256pp::seed_from_u64(0x5CA9);
        let mut buf = vec![0.0f64; len];
        for v in buf.iter_mut() {
            *v = rng.next_f64() * 12.0;
        }
        // bitwise parity, including ragged tails
        for n in [0, 1, 2, 3, 4, 5, 7, 63, len - 1, len] {
            let seq = buf[..n]
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(
                scan_max4(&buf[..n]).to_bits(),
                seq.to_bits(),
                "scan_max4 must equal the sequential fold (len {n})"
            );
        }
        let reps = if smoke { 200 } else { 2000 };
        let t_before = bench(reps, || {
            buf.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        });
        let t_after = bench(reps, || scan_max4(&buf));
        perf.record_ba(
            "simd_scan_rate",
            "Melem/s (16k f64 max-reduce)",
            len as f64 / t_before / 1e6,
            len as f64 / t_after / 1e6,
        );
        gate("simd_scan_rate", t_before, t_after, 1.5, smoke);
    }

    // ---- parallel sweep scaling --------------------------------------
    // Grid-points/s, serial scalar vs thread pool vs thread pool +
    // seed-axis batching. A ring comm model so each step walks a
    // compiled schedule — the cost the ReplicaBatch seed axis
    // amortizes; on the fixed-T^c model the batched arm would degrade
    // to scalar stepping and measure nothing.
    let mut sweep_cfg = paper_cluster(16);
    sweep_cfg.topology = Some(TopologyKind::Ring);
    sweep_cfg.link_latency = 25e-6;
    sweep_cfg.link_bandwidth = 12.5e9;
    sweep_cfg.grad_bytes = 4.0 * 335e6;
    let sweep_spec = SweepSpec::new(sweep_cfg)
        .workers(&[8, 16, 24, 32])
        .thresholds(&[0.0, 9.0])
        .seeds(&[1, 2, 3, 4])
        .iters(if smoke { 10 } else { 30 })
        .progress(false);
    let n_points = sweep_spec.len() as f64;
    let t0 = Instant::now();
    let serial = sweep_spec.clone().jobs(1).run();
    let t_serial = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = sweep_spec.clone().jobs(4).run();
    let t_parallel = t0.elapsed().as_secs_f64();
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(
            a.mean_iter_time.to_bits(),
            b.mean_iter_time.to_bits(),
            "parallel sweep must be bitwise identical to serial"
        );
    }
    // after arm: threads AND seed-axis batching (4 seeds -> one
    // ReplicaBatch per non-seed grid coordinate), still bitwise equal
    let t0 = Instant::now();
    let batched = sweep_spec.clone().jobs(4).batch(4).run();
    let t_batched = t0.elapsed().as_secs_f64();
    for (a, b) in serial.points.iter().zip(&batched.points) {
        assert_eq!(
            a.mean_iter_time.to_bits(),
            b.mean_iter_time.to_bits(),
            "batched sweep must be bitwise identical to serial"
        );
        assert_eq!(
            a.throughput.to_bits(),
            b.throughput.to_bits(),
            "batched sweep throughput must be bitwise identical"
        );
        assert_eq!(
            a.drop_rate.to_bits(),
            b.drop_rate.to_bits(),
            "batched sweep drop_rate must be bitwise identical"
        );
    }
    perf.record_ba(
        "sweep_points_per_sec",
        "points/s (serial -> jobs4+batch4)",
        n_points / t_serial,
        n_points / t_batched,
    );
    perf.record(
        "sweep_batch4_vs_jobs4",
        "x vs jobs4 unbatched",
        t_parallel / t_batched,
        f(t_parallel / t_batched, 2),
    );
    perf.record(
        "sweep_scaling_jobs4",
        "x vs serial",
        t_serial / t_parallel,
        f(t_serial / t_parallel, 2),
    );

    // ---- Algorithm 2 sweep -------------------------------------------
    let cfg = paper_cluster(200);
    let mut cal = ClusterSim::new(&cfg, 2);
    let trace = cal.record_trace(20);
    let per = bench(if smoke { 1 } else { 3 }, || choose_threshold(&trace, 256).tau);
    perf.record(
        "algorithm2_n200_grid256",
        "ms",
        per * 1e3,
        f(per * 1e3, 1),
    );

    // ---- PJRT grad step + upload overhead ----------------------------
    // Needs `make artifacts` + real xla bindings; with the in-tree stub
    // the load fails fast and the section is skipped (the sim/sweep
    // sections above are the ones tracked across PRs).
    match ModelRuntime::load(std::path::Path::new("artifacts"), "tiny") {
        Ok(mut rt) => {
            let store = ParamStore::init(&rt.manifest, 0);
            let mut rng = Xoshiro256pp::seed_from_u64(3);
            let toks: Vec<i32> = (0..rt.manifest.tokens_per_microbatch())
                .map(|_| rng.next_below(rt.manifest.dims.vocab as u64) as i32)
                .collect();
            rt.upload_params(store.tensors()).unwrap();
            rt.grad(&toks).unwrap(); // warmup/compile
            let per_grad = bench(20, || rt.grad(&toks).unwrap().loss);
            let per_upload =
                bench(20, || rt.upload_params(store.tensors()).unwrap());
            // §Perf before/after: naive literal-per-call marshaling vs
            // the device-resident-buffer path used by the trainer.
            let per_unbuf = bench(20, || {
                rt.grad_unbuffered(store.tensors(), &toks).unwrap().loss
            });
            perf.record_ba(
                "pjrt_grad_microbatch_tiny",
                "ms",
                per_unbuf * 1e3,
                per_grad * 1e3,
            );
            perf.record(
                "pjrt_param_upload_tiny",
                "ms",
                per_upload * 1e3,
                f(per_upload * 1e3, 3),
            );
            perf.record(
                "pjrt_upload_compute_overhead",
                "%",
                100.0 * per_upload / per_grad,
                f(100.0 * per_upload / per_grad, 1),
            );
        }
        Err(e) => {
            println!("(PJRT section skipped: {e})");
        }
    }

    perf.table.print();

    // ---- machine-readable output -------------------------------------
    let json = perf.to_json();
    let doc = Json::parse(&json).expect("bench must emit valid JSON");
    let entries = doc.get("entries").unwrap().as_arr().unwrap();
    for want in [
        "sim_step_rate_ring_n64",
        "sim_step_rate_torus_n64",
        "dropcomm_step_rate",
        "policy_dispatch_rate",
        "obs_overhead",
        "trace_replay_rate",
        "noise_fill_rate",
        "batched_step_rate",
        "simd_scan_rate",
        "sweep_points_per_sec",
    ] {
        assert!(
            entries
                .iter()
                .any(|e| e.get("path").and_then(Json::as_str) == Some(want)),
            "missing perf entry {want}"
        );
    }
    std::fs::write("BENCH_perf.json", &json).expect("write BENCH_perf.json");
    println!("wrote BENCH_perf.json ({} entries)", entries.len());
}
