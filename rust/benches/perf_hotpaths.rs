//! §Perf — hot-path microbenchmarks for the L3 coordinator and runtime:
//! ring AllReduce bandwidth, event-queue throughput, simulator step
//! rate, Algorithm-2 sweep cost, PJRT grad-step + upload overhead.
//! Results are recorded in EXPERIMENTS.md §Perf.

mod common;

use std::time::Instant;

use common::{header, paper_cluster};
use dropcompute::analysis::choose_threshold;
use dropcompute::collective::{ring_all_reduce, ring_all_reduce_naive, Communicator};
use dropcompute::report::{f, Table};
use dropcompute::rng::Xoshiro256pp;
use dropcompute::runtime::ModelRuntime;
use dropcompute::sim::{ClusterSim, EventQueue};
use dropcompute::train::ParamStore;

fn bench<R>(reps: usize, mut body: impl FnMut() -> R) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(body());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    header("§Perf — L3/runtime hot paths", "coordinator must not be the bottleneck");
    let mut t = Table::new("hot paths", &["path", "metric", "value"]);

    // ---- ring AllReduce on gradient-sized buffers -------------------
    // Threads are pre-spawned and iterate in-thread so the measurement
    // excludes spawn cost; before = naive per-chunk allocation,
    // after = buffer-recycling implementation.
    fn measure_ring(n: usize, len: usize, reps: usize, naive: bool) -> f64 {
        let comms = Communicator::ring(n);
        let t0 = Instant::now();
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    for _ in 0..reps {
                        if naive {
                            ring_all_reduce_naive(&c, &mut buf);
                        } else {
                            ring_all_reduce(&c, &mut buf);
                        }
                    }
                    buf[0]
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        t0.elapsed().as_secs_f64() / reps as f64
    }
    for (n, len) in [(4usize, 1_000_000usize), (8, 1_000_000), (8, 8_000_000)] {
        let reps = 8;
        let before = measure_ring(n, len, reps, true);
        let after = measure_ring(n, len, reps, false);
        // algorithmic bytes moved per worker: 2(N-1)/N * 4*len
        let alg = 2.0 * (n - 1) as f64 / n as f64 * 4.0 * len as f64;
        t.row(vec![
            format!("ring_all_reduce N={n} len={}M", len / 1_000_000),
            "GB/s/worker before->after".into(),
            format!("{} -> {} (x{})", f(alg / before / 1e9, 2),
                    f(alg / after / 1e9, 2), f(before / after, 2)),
        ]);
    }

    // ---- event queue -------------------------------------------------
    let per = bench(20, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at((i % 97) as f64, i);
        }
        while q.pop().is_some() {}
        q.processed()
    });
    t.row(vec![
        "event queue 10k schedule+pop".into(),
        "Mops/s".into(),
        f(20_000.0 / per / 1e6, 2),
    ]);

    // ---- cluster simulator steps --------------------------------------
    let cfg = paper_cluster(200);
    let mut sim = ClusterSim::new(&cfg, 1);
    let per = bench(200, || sim.step(Some(9.0)).iter_time);
    t.row(vec![
        "ClusterSim::step N=200 M=12".into(),
        "steps/s".into(),
        f(1.0 / per, 0),
    ]);

    // ---- Algorithm 2 sweep -------------------------------------------
    let mut cal = ClusterSim::new(&cfg, 2);
    let trace = cal.record_trace(20);
    let per = bench(3, || choose_threshold(&trace, 256).tau);
    t.row(vec![
        "Algorithm 2 (N=200, I=20, grid=256)".into(),
        "ms".into(),
        f(per * 1e3, 1),
    ]);

    // ---- PJRT grad step + upload overhead ------------------------------
    let mut rt = ModelRuntime::load(std::path::Path::new("artifacts"), "tiny")
        .expect("run `make artifacts` first");
    let store = ParamStore::init(&rt.manifest, 0);
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let toks: Vec<i32> = (0..rt.manifest.tokens_per_microbatch())
        .map(|_| rng.next_below(rt.manifest.dims.vocab as u64) as i32)
        .collect();
    rt.upload_params(store.tensors()).unwrap();
    rt.grad(&toks).unwrap(); // warmup/compile
    let per_grad = bench(20, || rt.grad(&toks).unwrap().loss);
    let per_upload = bench(20, || rt.upload_params(store.tensors()).unwrap());
    // §Perf before/after: naive literal-per-call marshaling vs the
    // device-resident-buffer path used by the trainer.
    let per_unbuf =
        bench(20, || rt.grad_unbuffered(store.tensors(), &toks).unwrap().loss);
    t.row(vec![
        "PJRT grad UNBUFFERED (before)".into(),
        "ms".into(),
        f(per_unbuf * 1e3, 2),
    ]);
    t.row(vec![
        "buffered speedup (after/before)".into(),
        "x".into(),
        f(per_unbuf / per_grad, 2),
    ]);
    t.row(vec![
        "PJRT grad microbatch (tiny)".into(),
        "ms".into(),
        f(per_grad * 1e3, 2),
    ]);
    t.row(vec![
        "param upload (tiny, 0.13M)".into(),
        "ms".into(),
        f(per_upload * 1e3, 3),
    ]);
    t.row(vec![
        "upload/compute overhead".into(),
        "%".into(),
        f(100.0 * per_upload / per_grad, 1),
    ]);

    t.print();
    println!("(paste these rows into EXPERIMENTS.md §Perf)");
}
