//! §Perf — hot-path microbenchmarks for the L3 coordinator and runtime:
//! ring AllReduce bandwidth, event-queue throughput, simulator step
//! rate (compiled vs event-queue schedule timing), parallel sweep
//! scaling, Algorithm-2 sweep cost, PJRT grad-step + upload overhead.
//!
//! Besides the human-readable table, emits `BENCH_perf.json` — one
//! entry per path with `metric`, `value` and (where the path has a
//! before/after comparison) both arms — so the perf trajectory is
//! machine-trackable across PRs.

mod common;

use std::time::Instant;

use common::{header, paper_cluster};
use dropcompute::analysis::choose_threshold;
use dropcompute::collective::{ring_all_reduce, ring_all_reduce_naive, Communicator};
use dropcompute::report::{f, Table};
use dropcompute::rng::Xoshiro256pp;
use dropcompute::runtime::json::Json;
use dropcompute::runtime::ModelRuntime;
use dropcompute::sim::{ClusterSim, EventQueue, StepOutcome};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;
use dropcompute::train::ParamStore;

fn bench<R>(reps: usize, mut body: impl FnMut() -> R) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(body());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// One machine-readable measurement: `before`/`after` are both set when
/// the path is a before/after comparison (then `value == after`).
struct Entry {
    path: String,
    metric: String,
    value: f64,
    before: Option<f64>,
    after: Option<f64>,
}

struct Perf {
    table: Table,
    entries: Vec<Entry>,
}

impl Perf {
    fn new() -> Self {
        Self {
            table: Table::new("hot paths", &["path", "metric", "value"]),
            entries: Vec::new(),
        }
    }

    fn record(&mut self, path: &str, metric: &str, value: f64, shown: String) {
        self.table.row(vec![path.into(), metric.into(), shown]);
        self.entries.push(Entry {
            path: path.into(),
            metric: metric.into(),
            value,
            before: None,
            after: None,
        });
    }

    fn record_ba(
        &mut self,
        path: &str,
        metric: &str,
        before: f64,
        after: f64,
    ) {
        self.table.row(vec![
            path.into(),
            format!("{metric} before->after"),
            format!("{} -> {} (x{})", f(before, 2), f(after, 2), f(after / before, 2)),
        ]);
        self.entries.push(Entry {
            path: path.into(),
            metric: metric.into(),
            value: after,
            before: Some(before),
            after: Some(after),
        });
    }

    fn to_json(&self) -> String {
        let mut s =
            String::from("{\n  \"bench\": \"perf_hotpaths\",\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"path\": \"{}\", \"metric\": \"{}\", \"value\": {:?}",
                e.path, e.metric, e.value
            ));
            if let (Some(b), Some(a)) = (e.before, e.after) {
                s.push_str(&format!(", \"before\": {b:?}, \"after\": {a:?}"));
            }
            s.push_str(&format!(
                "}}{}\n",
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn main() {
    header("§Perf — L3/runtime hot paths", "coordinator must not be the bottleneck");
    let mut perf = Perf::new();

    // ---- ring AllReduce on gradient-sized buffers -------------------
    // Threads are pre-spawned and iterate in-thread so the measurement
    // excludes spawn cost; before = naive per-chunk allocation,
    // after = buffer-recycling implementation.
    fn measure_ring(n: usize, len: usize, reps: usize, naive: bool) -> f64 {
        let comms = Communicator::ring(n);
        let t0 = Instant::now();
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    for _ in 0..reps {
                        if naive {
                            ring_all_reduce_naive(&c, &mut buf);
                        } else {
                            ring_all_reduce(&c, &mut buf);
                        }
                    }
                    buf[0]
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        t0.elapsed().as_secs_f64() / reps as f64
    }
    for (n, len) in [(4usize, 1_000_000usize), (8, 1_000_000), (8, 8_000_000)] {
        let reps = 8;
        let before = measure_ring(n, len, reps, true);
        let after = measure_ring(n, len, reps, false);
        // algorithmic bytes moved per worker: 2(N-1)/N * 4*len
        let alg = 2.0 * (n - 1) as f64 / n as f64 * 4.0 * len as f64;
        perf.record_ba(
            &format!("ring_all_reduce_n{n}_len{}M", len / 1_000_000),
            "GB/s/worker",
            alg / before / 1e9,
            alg / after / 1e9,
        );
    }

    // ---- event queue -------------------------------------------------
    let per = bench(20, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at((i % 97) as f64, i);
        }
        while q.pop().is_some() {}
        q.processed()
    });
    perf.record(
        "event_queue_10k",
        "Mops/s",
        20_000.0 / per / 1e6,
        f(20_000.0 / per / 1e6, 2),
    );

    // ---- simulator step rate: compiled vs event-queue timing ---------
    // The acceptance path of the perf PR: at N=64 on a schedule-driven
    // comm model, the compiled heapless pass vs the per-phase event
    // queue (both bitwise identical in output).
    for (label, kind) in [
        ("ring", TopologyKind::Ring),
        ("torus", TopologyKind::Torus { rows: 0 }),
    ] {
        let mut cfg = paper_cluster(64);
        cfg.topology = Some(kind);
        cfg.link_latency = 25e-6;
        cfg.link_bandwidth = 12.5e9;
        cfg.grad_bytes = 4.0 * 335e6;

        // sanity: the two arms agree bitwise before we time them
        let mut a = ClusterSim::new(&cfg, 7);
        let mut b = ClusterSim::new(&cfg, 7).with_reference_timing();
        for _ in 0..3 {
            assert_eq!(
                a.step(Some(9.0)).iter_time.to_bits(),
                b.step(Some(9.0)).iter_time.to_bits(),
                "compiled and reference timing must agree ({label})"
            );
        }

        let reps = 60;
        let mut slow = ClusterSim::new(&cfg, 7).with_reference_timing();
        let t_before = bench(reps, || slow.step(Some(9.0)).iter_time);
        let mut fast = ClusterSim::new(&cfg, 7);
        let mut out = StepOutcome::default();
        let t_after = bench(reps, || {
            fast.step_into(Some(9.0), &mut out);
            out.iter_time
        });
        perf.record_ba(
            &format!("sim_step_rate_{label}_n64"),
            "steps/s",
            1.0 / t_before,
            1.0 / t_after,
        );
        // regression tripwire, loose enough to survive a loaded
        // machine; the acceptance target (>=5x) is judged from the
        // recorded BENCH_perf.json numbers, not asserted here
        assert!(
            t_before / t_after > 1.0,
            "{label}: compiled path should beat the event queue \
             ({:.0} vs {:.0} steps/s)",
            1.0 / t_after,
            1.0 / t_before,
        );
        if t_before / t_after < 5.0 {
            println!(
                "WARNING: {label} compiled speedup only x{:.2} \
                 (machine load?)",
                t_before / t_after
            );
        }
    }

    // ---- parallel sweep scaling --------------------------------------
    // Grid-points/s, serial vs 4 jobs, on the fixed-T^c model (cheap
    // steps => scheduler overhead is what's being measured).
    let sweep_spec = SweepSpec::new(paper_cluster(16))
        .workers(&[8, 16, 24, 32])
        .thresholds(&[0.0, 9.0])
        .seeds(&[1, 2, 3, 4])
        .iters(30)
        .progress(false);
    let n_points = sweep_spec.len() as f64;
    let t0 = Instant::now();
    let serial = sweep_spec.clone().jobs(1).run();
    let t_serial = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = sweep_spec.clone().jobs(4).run();
    let t_parallel = t0.elapsed().as_secs_f64();
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(
            a.mean_iter_time.to_bits(),
            b.mean_iter_time.to_bits(),
            "parallel sweep must be bitwise identical to serial"
        );
    }
    perf.record_ba(
        "sweep_points_per_sec",
        "points/s",
        n_points / t_serial,
        n_points / t_parallel,
    );
    perf.record(
        "sweep_scaling_jobs4",
        "x vs serial",
        t_serial / t_parallel,
        f(t_serial / t_parallel, 2),
    );

    // ---- Algorithm 2 sweep -------------------------------------------
    let cfg = paper_cluster(200);
    let mut cal = ClusterSim::new(&cfg, 2);
    let trace = cal.record_trace(20);
    let per = bench(3, || choose_threshold(&trace, 256).tau);
    perf.record(
        "algorithm2_n200_grid256",
        "ms",
        per * 1e3,
        f(per * 1e3, 1),
    );

    // ---- PJRT grad step + upload overhead ----------------------------
    // Needs `make artifacts` + real xla bindings; with the in-tree stub
    // the load fails fast and the section is skipped (the sim/sweep
    // sections above are the ones tracked across PRs).
    match ModelRuntime::load(std::path::Path::new("artifacts"), "tiny") {
        Ok(mut rt) => {
            let store = ParamStore::init(&rt.manifest, 0);
            let mut rng = Xoshiro256pp::seed_from_u64(3);
            let toks: Vec<i32> = (0..rt.manifest.tokens_per_microbatch())
                .map(|_| rng.next_below(rt.manifest.dims.vocab as u64) as i32)
                .collect();
            rt.upload_params(store.tensors()).unwrap();
            rt.grad(&toks).unwrap(); // warmup/compile
            let per_grad = bench(20, || rt.grad(&toks).unwrap().loss);
            let per_upload =
                bench(20, || rt.upload_params(store.tensors()).unwrap());
            // §Perf before/after: naive literal-per-call marshaling vs
            // the device-resident-buffer path used by the trainer.
            let per_unbuf = bench(20, || {
                rt.grad_unbuffered(store.tensors(), &toks).unwrap().loss
            });
            perf.record_ba(
                "pjrt_grad_microbatch_tiny",
                "ms",
                per_unbuf * 1e3,
                per_grad * 1e3,
            );
            perf.record(
                "pjrt_param_upload_tiny",
                "ms",
                per_upload * 1e3,
                f(per_upload * 1e3, 3),
            );
            perf.record(
                "pjrt_upload_compute_overhead",
                "%",
                100.0 * per_upload / per_grad,
                f(100.0 * per_upload / per_grad, 1),
            );
        }
        Err(e) => {
            println!("(PJRT section skipped: {e})");
        }
    }

    perf.table.print();

    // ---- machine-readable output -------------------------------------
    let json = perf.to_json();
    let doc = Json::parse(&json).expect("bench must emit valid JSON");
    let entries = doc.get("entries").unwrap().as_arr().unwrap();
    for want in ["sim_step_rate_ring_n64", "sim_step_rate_torus_n64", "sweep_points_per_sec"] {
        assert!(
            entries
                .iter()
                .any(|e| e.get("path").and_then(Json::as_str) == Some(want)),
            "missing perf entry {want}"
        );
    }
    std::fs::write("BENCH_perf.json", &json).expect("write BENCH_perf.json");
    println!("wrote BENCH_perf.json ({} entries)", entries.len());
}
