//! Design-choice ablation (DESIGN.md §Perf): ring vs tree vs naive
//! all-reduce, measured on real gradient-sized buffers and in the
//! analytical timing model. The paper takes the decentralized ring as
//! given (§2); this quantifies why.

mod common;

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use common::header;
use dropcompute::collective::{
    naive_all_reduce, ring_all_reduce, tree_all_reduce, Communicator, MeshComm,
};
use dropcompute::report::{f, Table};
use dropcompute::sim::CommModel;

fn measure_ring(n: usize, len: usize, reps: usize) -> f64 {
    let comms = Communicator::ring(n);
    let t0 = Instant::now();
    let handles: Vec<_> = comms
        .into_iter()
        .map(|c| {
            thread::spawn(move || {
                let mut buf = vec![1.0f32; len];
                for _ in 0..reps {
                    ring_all_reduce(&c, &mut buf);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn measure_mesh(n: usize, len: usize, reps: usize, tree: bool) -> f64 {
    let comms = MeshComm::<f32>::full(n);
    let tree = Arc::new(tree);
    let t0 = Instant::now();
    let handles: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let tree = Arc::clone(&tree);
            thread::spawn(move || {
                let mut buf = vec![1.0f32; len];
                for _ in 0..reps {
                    if *tree {
                        tree_all_reduce(&c, &mut buf);
                    } else {
                        naive_all_reduce(&c, &mut buf);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    header(
        "Ablation — all-reduce algorithm choice",
        "ring is bandwidth-optimal (the large-gradient regime of data-\
         parallel LM training); tree wins only tiny payloads; naive loses \
         everywhere at scale",
    );
    let mut t = Table::new(
        "measured all-reduce time (ms)",
        &["N", "len", "ring", "tree", "naive"],
    );
    let mut rows = Vec::new();
    for (n, len, reps) in [
        (8usize, 1_000usize, 200usize),
        (8, 1_000_000, 10),
        (8, 8_000_000, 4),
        (4, 1_000_000, 10),
    ] {
        let ring = measure_ring(n, len, reps);
        let tree = measure_mesh(n, len, reps, true);
        let naive = measure_mesh(n, len, reps, false);
        t.row(vec![
            n.to_string(),
            len.to_string(),
            f(ring * 1e3, 2),
            f(tree * 1e3, 2),
            f(naive * 1e3, 2),
        ]);
        rows.push((n, len, ring, tree, naive));
    }
    t.print();

    // analytical T^c model comparison at cluster scale
    let bytes = 4.0 * 33.7e6; // `large` model gradient
    let mut t2 = Table::new(
        "analytical serial latency T^c for a 33.7M-param gradient (s)",
        &["N", "ring (bw-optimal)", "tree 2logN full-buffer"],
    );
    for n in [8usize, 64, 200] {
        let ring = CommModel::Ring { latency: 25e-6, bandwidth: 12.5e9, bytes }
            .serial_latency(n);
        let hops = 2.0 * (n as f64).log2().ceil();
        let tree = hops * (25e-6 + bytes / 12.5e9);
        t2.row(vec![n.to_string(), f(ring, 4), f(tree, 4)]);
    }
    t2.print();

    // shape: at the big-gradient sizes ring beats naive, and tree does
    // not beat ring by more than the latency regime allows.
    let big = rows.iter().find(|r| r.1 == 8_000_000).unwrap();
    assert!(big.2 < big.4, "ring must beat naive on big buffers");
    println!("\nSHAPE CHECK PASSED: ring wins the large-gradient regime \
              (ring {:.1} ms vs naive {:.1} ms at 8x8M)",
             big.2 * 1e3, big.4 * 1e3);
}
