//! Real-socket loopback latency — what the sim-to-real bridge costs.
//!
//! Two sections:
//!
//! 1. **Collective floor.** Per topology, the wall time of one socket
//!    (UDS) all-reduce vs the in-process mpsc mesh on the same
//!    schedule. The gap is pure transport overhead (syscalls, framing,
//!    copies) — the real-world constant the simulator's link model
//!    abstracts away.
//! 2. **Harness step rate.** A full `run_loopback` with a mid-run kill:
//!    steps per second including membership rounds and fault handling,
//!    plus both acceptance gates (bitwise replay, ordering
//!    conformance) asserted in-process.
//!
//! `--smoke` shrinks sizes for CI.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::header;
use dropcompute::collective::{topology_all_reduce, MeshComm};
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, Table};
use dropcompute::runtime::json::Json;
use dropcompute::sim::FaultPlan;
use dropcompute::topology::TopologyKind;
use dropcompute::transport::{
    bind_mesh, replay_bitwise, run_loopback, transport_all_reduce,
    RetryPolicy, RunSpec, SocketMesh, TransportKind,
};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "dropcompute-tbench-{}-{}-{}",
        std::process::id(),
        tag,
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Slowest rank's mean seconds per socket all-reduce.
fn socket_op_secs(
    topo: TopologyKind,
    n: usize,
    len: usize,
    iters: usize,
) -> f64 {
    let dir = scratch_dir(topo.name());
    let (bindings, endpoints) =
        bind_mesh(TransportKind::Uds, n, &dir).unwrap();
    let eps = Arc::new(endpoints);
    let mut handles = Vec::new();
    for binding in bindings {
        let eps = Arc::clone(&eps);
        handles.push(std::thread::spawn(move || {
            let rank = binding.rank;
            let mesh = SocketMesh::<f32>::establish(
                binding,
                &eps,
                RetryPolicy::default(),
                Duration::from_secs(20),
            )
            .unwrap();
            let mut buf: Vec<f32> =
                (0..len).map(|i| (rank + i) as f32).collect();
            let start = Instant::now();
            for step in 0..iters {
                transport_all_reduce(
                    &mesh,
                    topo,
                    step as u64,
                    &mut buf,
                    Duration::from_secs(20),
                )
                .unwrap();
            }
            start.elapsed().as_secs_f64() / iters as f64
        }));
    }
    let secs = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .fold(0.0f64, f64::max);
    std::fs::remove_dir_all(&dir).ok();
    secs
}

/// Slowest rank's mean seconds per mpsc all-reduce.
fn mpsc_op_secs(topo: TopologyKind, n: usize, len: usize, iters: usize) -> f64 {
    let handles: Vec<_> = MeshComm::<f32>::full(n)
        .into_iter()
        .map(|comm| {
            std::thread::spawn(move || {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (comm.rank + i) as f32).collect();
                let start = Instant::now();
                for _ in 0..iters {
                    topology_all_reduce(&comm, topo, &mut buf);
                }
                start.elapsed().as_secs_f64() / iters as f64
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .fold(0.0f64, f64::max)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    header(
        "Real-socket loopback — transport overhead + harness step rate",
        "the paper's drops are timing decisions; this measures what the \
         real clock adds on top of the simulated one",
    );
    if smoke {
        println!("(smoke mode: reduced sizes)");
    }

    let n = 4;
    let len = if smoke { 256 } else { 4096 };
    let iters = if smoke { 6 } else { 40 };

    let mut t = Table::new(
        format!("all-reduce wall time, N={n} len={len} iters={iters}"),
        &["topology", "socket ms/op", "mpsc ms/op", "ratio"],
    );
    let mut json = String::from("{\n  \"bench\": \"transport_loopback\",\n");
    json.push_str(&format!("  \"n\": {n}, \"len\": {len},\n"));
    json.push_str("  \"collectives\": [\n");
    for (ti, topo) in TopologyKind::ALL.iter().enumerate() {
        let socket = socket_op_secs(*topo, n, len, iters);
        let mpsc = mpsc_op_secs(*topo, n, len, iters);
        t.row(vec![
            topo.name().to_string(),
            f(socket * 1e3, 3),
            f(mpsc * 1e3, 3),
            f(socket / mpsc.max(1e-12), 2),
        ]);
        json.push_str(&format!(
            "    {{\"topology\": \"{}\", \"socket_ms\": {:.4}, \
             \"mpsc_ms\": {:.4}}}{}\n",
            topo.name(),
            socket * 1e3,
            mpsc * 1e3,
            if ti + 1 < TopologyKind::ALL.len() { "," } else { "" },
        ));
    }
    t.print();
    json.push_str("  ],\n");

    // ---- harness step rate under churn -----------------------------
    let steps = if smoke { 4 } else { 12 };
    let spec = RunSpec {
        workers: n,
        accums: 2,
        iters: steps,
        kind: TransportKind::Uds,
        topo: TopologyKind::Ring,
        policy: DropPolicy::parse("deadline=0.25").unwrap(),
        plan: Some(FaultPlan::parse("kill@1:w3").unwrap()),
        retry: RetryPolicy::default(),
        recv_deadline: Duration::from_secs(5),
        compute_ms: 2.0,
        skew_ms: 5.0,
        min_gap: 0.1,
        grad_len: len,
        seed: 0xBE9C,
        dir: None,
        latency: 25e-6,
        bandwidth: 12.5e9,
        bytes: len as f64 * 4.0,
    };
    let start = Instant::now();
    let report = run_loopback(&spec, None).expect("loopback run");
    let wall = start.elapsed().as_secs_f64();
    let replayed = replay_bitwise(&report.trace).expect("bitwise replay");

    let mut t = Table::new(
        "loopback harness, ring N=4 with kill@1:w3",
        &["metric", "value"],
    );
    t.row(vec!["steps".into(), steps.to_string()]);
    t.row(vec!["wall (s)".into(), f(wall, 3)]);
    t.row(vec!["steps/s".into(), f(steps as f64 / wall, 2)]);
    t.row(vec![
        "degraded steps".into(),
        report.stats.degraded_steps.to_string(),
    ]);
    t.row(vec!["replayed bitwise".into(), replayed.to_string()]);
    t.row(vec!["conformance".into(), format!("{}", report.conformance)]);
    t.print();
    json.push_str(&format!(
        "  \"harness\": {{\"steps\": {steps}, \"wall_s\": {wall:.4}, \
         \"replayed\": {replayed}, \"conformance_passed\": {}}}\n}}\n",
        report.conformance.passed(),
    ));

    println!("JSON_BEGIN");
    print!("{json}");
    println!("JSON_END");

    // shape checks: the emitted JSON must parse, every topology must be
    // covered, and both acceptance gates must hold
    let doc = Json::parse(&json).expect("bench must emit valid JSON");
    assert_eq!(
        doc.get("collectives").unwrap().as_arr().unwrap().len(),
        TopologyKind::ALL.len()
    );
    assert_eq!(replayed as u64, steps);
    assert!(
        report.conformance.passed(),
        "conformance: {}",
        report.conformance
    );
    println!(
        "\nSHAPE CHECK PASSED: {} topologies, {} harness steps, both \
         gates green",
        TopologyKind::ALL.len(),
        steps
    );
}
