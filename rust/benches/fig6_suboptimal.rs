//! Figure 6 (App. A) — single-iteration latency histograms in a
//! sub-optimal system: per-worker heterogeneity + sporadic stragglers,
//! the setting where DropCompute recovered ~18% runtime.

mod common;

use common::header;
use dropcompute::analysis::choose_threshold;
use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::report::{f, pct};
use dropcompute::rng::Xoshiro256pp;
use dropcompute::sim::{ClusterSim, LatencyModel};
use dropcompute::stats::{Histogram, Welford};

/// A sub-optimal system: 10% of hosts run 15-35% slow (bad cooling /
/// noisy neighbours) and any worker can hiccup.
fn suboptimal(workers: usize, accums: usize, seed: u64) -> ClusterSim {
    let cfg = ClusterConfig {
        workers,
        accumulations: accums,
        microbatch_mean: 0.45,
        microbatch_std: 0.025,
        comm_latency: 0.5,
        noise: NoiseKind::Gamma { mean: 0.05, var: 0.01 },
        stragglers: StragglerKind::Uniform { p: 0.02, delay: 2.0 },
        ..Default::default()
    };
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let scales: Vec<f64> = (0..workers)
        .map(|_| {
            if rng.next_f64() < 0.10 {
                1.15 + 0.2 * rng.next_f64()
            } else {
                1.0
            }
        })
        .collect();
    let model = LatencyModel::from_config(&cfg).with_worker_scales(scales);
    ClusterSim::with_model(
        workers,
        accums,
        model,
        dropcompute::sim::CommModel::Fixed(cfg.comm_latency),
        seed,
    )
}

fn panel(name: &str, workers: usize, accums: usize, lo: f64, hi: f64) -> f64 {
    let mut sim = suboptimal(workers, accums, 61);
    let mut hist = Histogram::new(lo, hi, 40);
    let mut w = Welford::new();
    let iters = 80;
    for _ in 0..iters {
        let out = sim.step(None);
        hist.push(out.iter_time);
        w.push(out.iter_time);
    }
    println!("\n{name}: iteration latency, mean {:.2}s std {:.2}s", w.mean(), w.std());
    println!("  [{lo:.0}s .. {hi:.0}s] {}", hist.sparkline());

    // DropCompute recovery on the same system
    let mut cal = suboptimal(workers, accums, 62);
    let trace = cal.record_trace(20);
    let choice = choose_threshold(&trace, 192);
    let mut dc = suboptimal(workers, accums, 63);
    let mut w_dc = Welford::new();
    let mut completed = 0usize;
    for _ in 0..iters {
        let out = dc.step(Some(choice.tau));
        w_dc.push(out.iter_time);
        completed += out.total_completed();
    }
    let completion = completed as f64 / (iters * workers * accums) as f64;
    let speedup = w.mean() / w_dc.mean() * completion;
    println!(
        "  DropCompute(tau*={:.1}s): mean {:.2}s, drop {}, effective speedup x{}",
        choice.tau,
        w_dc.mean(),
        pct(1.0 - completion),
        f(speedup, 3)
    );
    speedup
}

fn main() {
    header(
        "Figure 6 — sub-optimal system latency histograms (App. A)",
        "long straggler tail before optimization; DropCompute recovered \
         ~18% with 162 workers / 64 accumulations",
    );
    let s1 = panel("162 workers, 64 accumulations", 162, 64, 28.0, 45.0);
    let s2 = panel("190 workers, 16 accumulations", 190, 16, 7.0, 14.0);

    assert!(s1 > 1.05, "64-accum system should recover >5%: x{s1:.3}");
    assert!(s1 > s2 * 0.95, "more accumulations amortize better here");
    println!(
        "\nSHAPE CHECK PASSED: recovery x{s1:.3} (paper ~1.18) and x{s2:.3}"
    );
}
