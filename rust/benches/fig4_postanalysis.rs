//! Figure 4 — effective speedup vs drop rate, post-analyzed from
//! no-drop traces with natural heterogeneity (no injected delay):
//! (left) M=32 accumulations, varying workers;
//! (right) 112 workers, varying accumulations.

mod common;

use common::header;
use dropcompute::analysis::{evaluate_threshold, threshold_for_drop_rate};
use dropcompute::config::ClusterConfig;
use dropcompute::report::{f, Table};
use dropcompute::sim::ClusterSim;

/// "Natural heterogeneity": no injected delay, only hardware jitter
/// (sigma/mu ~ 7% per micro-batch, as a busy-but-healthy cluster shows).
fn natural(workers: usize, accums: usize) -> ClusterConfig {
    ClusterConfig {
        workers,
        accumulations: accums,
        microbatch_mean: 0.45,
        microbatch_std: 0.033,
        comm_latency: 0.5,
        ..Default::default()
    }
}

fn speedup_at_drop_rates(cfg: &ClusterConfig, rates: &[f64]) -> Vec<f64> {
    let mut sim = ClusterSim::new(cfg, 41);
    let trace = sim.record_trace(50);
    rates
        .iter()
        .map(|&r| {
            let tau = threshold_for_drop_rate(&trace, r);
            evaluate_threshold(&trace, tau).effective_speedup
        })
        .collect()
}

fn main() {
    header(
        "Figure 4 — increasing benefit on a large scale (post-analysis)",
        "speedup grows with workers; diminishing returns with more \
         accumulations",
    );
    let rates = [0.005, 0.01, 0.02, 0.04, 0.08];

    // left: M=32, varying workers
    let ns = [16usize, 32, 64, 112];
    let mut t = Table::new(
        "Fig 4 (left) — S_eff vs drop rate, M=32",
        &["drop rate", "N=16", "N=32", "N=64", "N=112"],
    );
    let cols: Vec<Vec<f64>> = ns
        .iter()
        .map(|&n| speedup_at_drop_rates(&natural(n, 32), &rates))
        .collect();
    for (i, &r) in rates.iter().enumerate() {
        t.row(vec![
            format!("{:.1}%", r * 100.0),
            f(cols[0][i], 4),
            f(cols[1][i], 4),
            f(cols[2][i], 4),
            f(cols[3][i], 4),
        ]);
    }
    t.print();

    // right: N=112, varying accumulations
    let ms = [8usize, 16, 32, 64];
    let mut t2 = Table::new(
        "Fig 4 (right) — S_eff vs drop rate, N=112",
        &["drop rate", "M=8", "M=16", "M=32", "M=64"],
    );
    let cols2: Vec<Vec<f64>> = ms
        .iter()
        .map(|&m| speedup_at_drop_rates(&natural(112, m), &rates))
        .collect();
    for (i, &r) in rates.iter().enumerate() {
        t2.row(vec![
            format!("{:.1}%", r * 100.0),
            f(cols2[0][i], 4),
            f(cols2[1][i], 4),
            f(cols2[2][i], 4),
            f(cols2[3][i], 4),
        ]);
    }
    t2.print();

    // shape: more workers => more speedup at the same drop rate
    let mid = 2; // 2% drop rate
    assert!(
        cols[3][mid] > cols[0][mid],
        "N=112 ({}) should beat N=16 ({}) at equal drop rate",
        cols[3][mid],
        cols[0][mid]
    );
    // diminishing returns in M: speedup per accumulation shrinks
    let gain_8_16 = cols2[1][mid] - cols2[0][mid];
    let gain_32_64 = cols2[3][mid] - cols2[2][mid];
    assert!(
        gain_32_64 < gain_8_16 + 0.02,
        "M-returns should diminish: 8->16 {gain_8_16}, 32->64 {gain_32_64}"
    );
    println!(
        "\nSHAPE CHECK PASSED: speedup grows with N (x{:.3} -> x{:.3} at 2% \
         drop), diminishing returns in M",
        cols[0][mid], cols[3][mid]
    );
}
