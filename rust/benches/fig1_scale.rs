//! Figure 1 — scale graph: throughput of synchronous training vs N with
//! simulated compute variance; baseline vs DropCompute vs linear.
//! (left: measured ≤ 200 workers; right: Eq. 11 extrapolation to 2048.)

mod common;

use common::{header, paper_cluster};
use dropcompute::analysis::{extrapolate_speedup, Setting};
use dropcompute::coordinator::ScaleRun;
use dropcompute::report::{f, pct, Table};
use dropcompute::sim::LatencyModel;

fn main() {
    header(
        "Figure 1 — DropCompute improves robustness and scalability",
        "baseline bends away from linear as N grows; DropCompute stays \
         near-linear; the gain grows with N and extrapolates to infinity",
    );

    // Left panel: simulated measurement up to 200 workers. The sweep
    // engine fans the N-points over all cores; per-point seeding keeps
    // the output bitwise identical to a serial run.
    let run = ScaleRun {
        base: paper_cluster(1),
        calibration_iters: 15,
        measure_iters: 80,
        grid: 192,
        seed: 11,
        jobs: 0,
        ..ScaleRun::default()
    };
    let ns = [8usize, 16, 32, 64, 112, 160, 200];
    let pts = run.sweep(&ns);
    let mut t = Table::new(
        "Fig 1 (left) — measured, M=12, lognormal delay",
        &["N", "linear mb/s", "baseline mb/s", "DropCompute mb/s",
          "base eff", "dc eff", "speedup", "drop"],
    );
    for p in &pts {
        t.row(vec![
            p.workers.to_string(),
            f(p.linear_throughput, 1),
            f(p.baseline_throughput, 1),
            f(p.dropcompute_throughput, 1),
            pct(p.baseline_throughput / p.linear_throughput),
            pct(p.dropcompute_throughput / p.linear_throughput),
            f(p.dropcompute_throughput / p.baseline_throughput, 3),
            pct(p.drop_rate),
        ]);
    }
    t.print();

    // Right panel: analytical extrapolation (Eq. 11 + Eq. 4).
    let model = LatencyModel::from_config(&paper_cluster(1));
    let base = Setting {
        workers: 1,
        accums: 12,
        mu: model.mean(),
        sigma2: model.variance(),
        comm: 0.5,
    };
    let big_ns = [64usize, 128, 256, 512, 1024, 2048];
    let ext = extrapolate_speedup(&base, &big_ns, 256);
    let mut t2 = Table::new(
        "Fig 1 (right) — theoretical extrapolation (Eq. 11)",
        &["N", "E[T] base", "tau*", "S_eff(tau*)"],
    );
    for (n, speed) in &ext {
        let s = Setting { workers: *n, ..base };
        let (tau, _) = s.optimal_threshold(256);
        t2.row(vec![
            n.to_string(),
            f(s.expected_step_time(), 2),
            f(tau, 2),
            f(*speed, 4),
        ]);
    }
    t2.print();

    // Shape assertions (the claims the figure makes).
    let eff = |p: &dropcompute::coordinator::ScalePoint| {
        p.baseline_throughput / p.linear_throughput
    };
    assert!(eff(&pts[0]) > eff(pts.last().unwrap()),
        "baseline efficiency must degrade with N");
    assert!(ext.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-9),
        "extrapolated speedup must be nondecreasing in N");
    println!("\nSHAPE CHECK PASSED: baseline efficiency degrades \
              ({:.1}% -> {:.1}%), DropCompute speedup grows with N \
              (x{:.3} at N=200, extrapolated x{:.3} at N=2048)",
        eff(&pts[0]) * 100.0,
        eff(pts.last().unwrap()) * 100.0,
        pts.last().unwrap().dropcompute_throughput
            / pts.last().unwrap().baseline_throughput,
        ext.last().unwrap().1);
}
