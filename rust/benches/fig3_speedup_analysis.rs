//! Figure 3 — statistical characteristics of micro-batch latency give a
//! reliable estimate of S_eff: 'simulation' (replay of samples) vs
//! 'analytical' (Eq. 5 + Eq. 4) vs 'analytical given E[T]' (Eq. 5 +
//! measured E[T]); panel (c) the automatic optimum.

mod common;

use common::{header, paper_cluster};
use dropcompute::analysis::{choose_threshold, evaluate_threshold, Setting};
use dropcompute::config::NoiseKind;
use dropcompute::report::{f, pct, Table};
use dropcompute::sim::ClusterSim;

fn panel(title: &str, cfg: &dropcompute::config::ClusterConfig, iters: usize) {
    let mut sim = ClusterSim::new(cfg, 31);
    let trace = sim.record_trace(iters);
    let (mu, var) = trace.microbatch_moments();
    let setting = Setting {
        workers: cfg.workers,
        accums: cfg.accumulations,
        mu,
        sigma2: var,
        comm: cfg.comm_latency,
    };
    // measured E[T] for the 'analytical given E[T]' curve
    let e_t_measured = (0..trace.iters)
        .map(|i| trace.step_time(i))
        .sum::<f64>()
        / trace.iters as f64;

    let mut t = Table::new(
        title.to_string(),
        &["tau", "S_eff sim", "S_eff analytic", "analytic|E[T]"],
    );
    let lo = 0.55 * cfg.accumulations as f64 * mu;
    let hi = e_t_measured * 1.05;
    let mut max_gap: f64 = 0.0;
    for k in 0..10 {
        let tau = lo + (hi - lo) * k as f64 / 9.0;
        let sim_point = evaluate_threshold(&trace, tau);
        let analytic = setting.effective_speedup(tau);
        let given_t = setting.effective_speedup_given_t(tau, e_t_measured);
        max_gap = max_gap.max((sim_point.effective_speedup - given_t).abs());
        t.row(vec![
            f(tau, 2),
            f(sim_point.effective_speedup, 4),
            f(analytic, 4),
            f(given_t, 4),
        ]);
    }
    t.print();
    println!("max |sim - analytic|E[T]| over the sweep: {max_gap:.4}");
}

fn main() {
    header(
        "Figure 3 — analytical estimate of the effective speedup",
        "(a) normal noise: all three estimates agree; (b) heavy-tailed \
         (BERT-like) noise: pure-analytic E[T] is off, analytic-given-E[T] \
         tracks simulation; (c) automatic tau* at the S_eff maximum",
    );

    // (a) normal micro-batch latency
    let mut cfg_a = paper_cluster(64);
    cfg_a.noise = NoiseKind::Normal { mean: 0.6, var: 0.02 };
    panel("Fig 3a — t_n^(m) ~ Normal", &cfg_a, 60);

    // (b) the paper's lognormal simulated delay (heavy-tailed)
    let cfg_b = paper_cluster(64);
    panel("Fig 3b — t_n^(m) from BERT-like lognormal delay", &cfg_b, 60);

    // (c) the trade-off curves and automatic optimum
    let cfg_c = paper_cluster(64);
    let mut sim = ClusterSim::new(&cfg_c, 33);
    let trace = sim.record_trace(40);
    let choice = choose_threshold(&trace, 256);
    let mut t = Table::new(
        "Fig 3c — S_eff / completion rate / step speedup vs tau",
        &["tau", "S_eff", "completion", "step speedup"],
    );
    for p in choice.sweep.iter().step_by(choice.sweep.len() / 12) {
        t.row(vec![
            f(p.tau, 2),
            f(p.effective_speedup, 4),
            pct(p.completion_rate),
            f(p.step_speedup, 4),
        ]);
    }
    t.print();
    println!(
        "optimal tau* = {:.3}s  S_eff {:.4}  completion {:.1}%",
        choice.tau,
        choice.speedup,
        choice.completion_rate * 100.0
    );

    // shape: the optimum is interior (not at either end of the sweep)
    let first = choice.sweep.first().unwrap().effective_speedup;
    let last = choice.sweep.last().unwrap().effective_speedup;
    assert!(choice.speedup > first && choice.speedup > last,
        "S_eff must have an interior maximum: {first} .. {} .. {last}",
        choice.speedup);
    println!("\nSHAPE CHECK PASSED: interior maximum, analytic|E[T] tracks simulation");
}
