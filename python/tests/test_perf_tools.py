"""Tests for the L1/L2 perf-analysis tooling (roofline + HLO stats)."""

import os

import pytest

from compile import hlo_stats
from compile.kernels import roofline


class TestRoofline:
    def test_scores_ordered_by_merit(self):
        scores = roofline.sweep(512, 64)
        merits = [s.figure_of_merit() for s in scores]
        assert merits == sorted(merits, reverse=True)
        assert merits[0] > 0.0

    def test_vmem_budget_enforced(self):
        # Oversized blocks on long sequences must be marked infeasible.
        s = roofline.score(8192, 128, 256, 256)
        big = roofline.attention.vmem_bytes(256, 256, 8192, 128)
        assert s.fits == (big <= roofline.VMEM_BYTES)
        # and a clearly-infeasible fabricated case
        huge = roofline.BlockScore(1, 1, roofline.VMEM_BYTES + 1, 1.0, 10.0,
                                   fits=False)
        assert huge.figure_of_merit() == 0.0

    def test_mxu_aligned_blocks_win(self):
        # On a 128-lane MXU, 128-multiples should beat odd shapes.
        aligned = roofline.score(512, 64, 128, 128)
        odd = roofline.score(512, 64, 32, 32)
        assert aligned.mxu_utilization >= odd.mxu_utilization

    def test_intensity_grows_with_block_q(self):
        # Bigger q-blocks stream K/V fewer times -> higher intensity.
        small = roofline.score(2048, 64, 32, 64)
        large = roofline.score(2048, 64, 256, 64)
        assert large.arithmetic_intensity > small.arithmetic_intensity


class TestHloStats:
    @pytest.fixture(scope="class")
    def grad_path(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "test", "grad.hlo.txt")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        return path

    def test_counts_plausible(self, grad_path):
        r = hlo_stats.report(grad_path)
        assert r["total_ops"] > 50
        assert r["dot"] > 0, "a transformer grad must contain matmuls"

    def test_no_custom_calls_on_cpu(self, grad_path):
        # interpret=True must not leave Mosaic custom-calls behind.
        r = hlo_stats.report(grad_path)
        assert r["custom_calls"] == 0

    def test_layout_fraction_bounded(self, grad_path):
        r = hlo_stats.report(grad_path)
        assert r["layout_fraction"] < 0.6, (
            "layout ops dominating the module signals a lowering regression"
        )
