"""Kernel vs oracle: the core L1 correctness signal.

hypothesis sweeps shapes (incl. non-divisible-by-block sizes) and dtypes;
every case asserts allclose against the pure-jnp reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, ref

settings.register_profile("kernels", max_examples=10, deadline=None)
settings.load_profile("kernels")


def _qkv(bh, s, d, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    return tuple(
        jax.random.normal(jax.random.fold_in(key, i), (bh, s, d)).astype(dtype)
        for i in range(3)
    )


class TestFlashAttention:
    @given(
        bh=st.integers(1, 6),
        s=st.integers(2, 96),
        d=st.sampled_from([4, 8, 16, 32]),
        causal=st.booleans(),
    )
    def test_matches_reference(self, bh, s, d, causal):
        q, k, v = _qkv(bh, s, d, jnp.float32, seed=bh * 1000 + s)
        got = attention.flash_attention_fwd(q, k, v, causal=causal)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @given(
        block_q=st.sampled_from([8, 16, 64, 128]),
        block_k=st.sampled_from([8, 16, 64, 128]),
    )
    def test_block_shape_invariance(self, block_q, block_k):
        """Output must not depend on the BlockSpec tiling choice."""
        q, k, v = _qkv(2, 40, 16, jnp.float32, seed=7)
        got = attention.flash_attention_fwd(
            q, k, v, causal=True, block_q=block_q, block_k=block_k)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = _qkv(2, 32, 16, dtype)
        got = attention.flash_attention_fwd(q, k, v, causal=True)
        want = ref.attention(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32),
            rtol=tol, atol=tol)

    def test_causality(self):
        """Changing future keys must not change past outputs."""
        q, k, v = _qkv(1, 24, 8, jnp.float32, seed=3)
        out1 = attention.flash_attention_fwd(q, k, v, causal=True)
        k2 = k.at[:, 12:, :].set(99.0)
        v2 = v.at[:, 12:, :].set(-99.0)
        out2 = attention.flash_attention_fwd(q, k2, v2, causal=True)
        np.testing.assert_allclose(out1[:, :12], out2[:, :12],
                                   rtol=1e-6, atol=1e-6)

    def test_gradients_match_reference(self):
        q, k, v = _qkv(2, 20, 8, jnp.float32, seed=11)

        def f_kernel(q, k, v):
            return (attention.flash_attention(q, k, v, True) ** 2).sum()

        def f_ref(q, k, v):
            return (ref.attention(q, k, v, causal=True) ** 2).sum()

        for got, want in zip(jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v),
                             jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_single_query_row(self):
        q, k, v = _qkv(1, 1, 8, jnp.float32)
        got = attention.flash_attention_fwd(q, k, v, causal=True)
        np.testing.assert_allclose(got, v, rtol=1e-6, atol=1e-6)

    def test_vmem_estimate_monotone(self):
        a = attention.vmem_bytes(64, 64, 128, 64)
        b = attention.vmem_bytes(128, 128, 128, 64)
        assert 0 < a < b

    def test_mxu_estimate_range(self):
        for bq, bk, d in [(64, 64, 32), (128, 128, 64), (8, 8, 8)]:
            u = attention.mxu_utilization_estimate(bq, bk, d)
            assert 0.0 < u <= 1.0
        # full tiles -> full utilization
        assert attention.mxu_utilization_estimate(128, 128, 128) == 1.0


class TestLayerNorm:
    @given(
        rows=st.integers(1, 300),
        dim=st.sampled_from([8, 24, 64, 128]),
    )
    def test_matches_reference(self, rows, dim):
        key = jax.random.PRNGKey(rows * 7 + dim)
        x = jax.random.normal(key, (rows, dim), jnp.float32) * 3 + 1
        sc = jax.random.normal(jax.random.fold_in(key, 1), (dim,)) + 1.0
        bi = jax.random.normal(jax.random.fold_in(key, 2), (dim,))
        got = layernorm.layernorm_fwd(x, sc, bi)
        want = ref.layernorm(x, sc, bi)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    @given(block_rows=st.sampled_from([1, 16, 64, 256]))
    def test_block_shape_invariance(self, block_rows):
        key = jax.random.PRNGKey(5)
        x = jax.random.normal(key, (100, 32), jnp.float32)
        sc, bi = jnp.ones(32), jnp.zeros(32)
        got = layernorm.layernorm_fwd(x, sc, bi, block_rows=block_rows)
        want = ref.layernorm(x, sc, bi)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_normalized_moments(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 48)) * 5 + 2
        y = layernorm.layernorm_fwd(x, jnp.ones(48), jnp.zeros(48))
        np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
        np.testing.assert_allclose(jnp.var(y, -1), 1.0, rtol=1e-3, atol=1e-3)

    def test_gradients_match_reference(self):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (12, 16))
        sc = jnp.ones(16) * 1.3
        bi = jnp.zeros(16) + 0.1

        def f_k(x, sc, bi):
            return (layernorm.layernorm(x, sc, bi) ** 2).sum()

        def f_r(x, sc, bi):
            return (ref.layernorm(x, sc, bi) ** 2).sum()

        for got, want in zip(jax.grad(f_k, argnums=(0, 1, 2))(x, sc, bi),
                             jax.grad(f_r, argnums=(0, 1, 2))(x, sc, bi)):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
