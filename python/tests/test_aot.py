"""AOT pipeline tests: HLO text emission and local PJRT round-trip.

The Rust runtime consumes the same HLO text; round-tripping it through the
python xla_client here catches interchange breakage (e.g. the 64-bit-id
proto issue) before the cargo side ever sees it.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

CFG = model.CONFIGS["test"]


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts") / "test"
    entry = aot.lower_size(CFG, str(out))
    with open(out / "manifest.json", "w") as f:
        json.dump(entry, f)
    return str(out)


class TestEmission:
    def test_files_exist(self, artifact_dir):
        for name in ["grad.hlo.txt", "loss.hlo.txt", "manifest.json"]:
            assert os.path.exists(os.path.join(artifact_dir, name))

    def test_hlo_is_text(self, artifact_dir):
        with open(os.path.join(artifact_dir, "grad.hlo.txt")) as f:
            text = f.read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_manifest_schema(self, artifact_dir):
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            m = json.load(f)
        assert m["name"] == "test"
        assert m["param_count"] == model.param_count(CFG)
        assert len(m["params"]) == len(model.param_specs(CFG))
        assert m["entrypoints"]["grad"]["outputs"][0] == "loss"
        for p in m["params"]:
            assert p["init"] in ("normal", "zeros", "ones")

    def test_no_mosaic_custom_calls(self, artifact_dir):
        """interpret=True must lower Pallas to plain HLO (CPU-runnable)."""
        with open(os.path.join(artifact_dir, "grad.hlo.txt")) as f:
            text = f.read()
        assert "mosaic" not in text.lower()


class TestRoundTrip:
    def test_parse_roundtrip(self, artifact_dir):
        """The emitted text must re-parse (this is where 64-bit-id protos
        would explode) and convert back to an XlaComputation."""
        from jax._src.lib import xla_client as xc
        with open(os.path.join(artifact_dir, "grad.hlo.txt")) as f:
            text = f.read()
        mod = xc._xla.hlo_module_from_text(text)
        comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
        assert comp.program_shape() is not None

    def test_compile_and_execute_matches_jax(self, artifact_dir):
        """Parse the emitted text with xla_client, run it, compare to jax.

        Mirrors the Rust runtime path: text -> module -> compile -> execute.
        """
        from jax._src.lib import xla_client as xc
        from jaxlib import _jax
        with open(os.path.join(artifact_dir, "grad.hlo.txt")) as f:
            text = f.read()
        proto = xc._xla.hlo_module_from_text(text) \
            .as_serialized_hlo_module_proto()
        mlir = xc._xla.mlir.xla_computation_to_mlir_module(
            xc.XlaComputation(proto))

        params = model.init_params(CFG, jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (CFG.micro_batch, CFG.seq_len), 0,
            CFG.vocab)

        backend = jax.devices("cpu")[0].client
        dl = _jax.DeviceList(tuple(backend.devices()[:1]))
        exe = backend.compile_and_load(mlir, dl)
        args = [np.asarray(p) for p in params] + [np.asarray(tokens, np.int32)]
        bufs = [backend.buffer_from_pyval(a) for a in args]
        outs = exe.execute(bufs)
        got = [np.asarray(o) for o in outs]

        want = model.grad_step(CFG, params, tokens)
        assert len(got) == len(want)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
        for g, w in zip(got[1:], want[1:]):
            np.testing.assert_allclose(g, w, rtol=5e-4, atol=1e-5)
