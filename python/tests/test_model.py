"""L2 model tests: shapes, loss semantics, gradient correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.CONFIGS["test"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(1), (CFG.micro_batch, CFG.seq_len), 0, CFG.vocab)


class TestForward:
    def test_logits_shape(self, params, tokens):
        logits = model.forward(CFG, params, tokens)
        assert logits.shape == (CFG.micro_batch, CFG.seq_len, CFG.vocab)
        assert logits.dtype == jnp.float32

    def test_causality(self, params, tokens):
        """Perturbing a future token must not change earlier logits."""
        cut = CFG.seq_len // 2
        base = model.forward(CFG, params, tokens)
        toks2 = tokens.at[:, cut:].set((tokens[:, cut:] + 1) % CFG.vocab)
        pert = model.forward(CFG, params, toks2)
        np.testing.assert_allclose(base[:, :cut], pert[:, :cut],
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(base[:, cut:], pert[:, cut:])

    def test_pallas_matches_reference_path(self, params, tokens):
        """use_pallas=False (pure jnp) must agree with the kernel path."""
        import dataclasses
        ref_cfg = dataclasses.replace(CFG, use_pallas=False)
        a = model.forward(CFG, params, tokens)
        b = model.forward(ref_cfg, params, tokens)
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


class TestLoss:
    def test_initial_loss_near_uniform(self, params, tokens):
        """With tiny init, loss should be ~log(vocab)."""
        loss = model.loss_fn(CFG, params, tokens)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.3

    def test_loss_is_scalar_finite(self, params, tokens):
        loss = model.loss_fn(CFG, params, tokens)
        assert loss.shape == ()
        assert np.isfinite(float(loss))

    def test_memorizes_constant_sequence(self, params):
        """A few SGD steps on one repeated batch must reduce the loss."""
        toks = jnp.tile(jnp.arange(CFG.seq_len, dtype=jnp.int32) % CFG.vocab,
                        (CFG.micro_batch, 1))
        p = list(params)
        l0 = float(model.loss_fn(CFG, p, toks))
        grad_fn = jax.jit(
            lambda ps: model.grad_step(CFG, list(ps), toks))
        for _ in range(20):
            out = grad_fn(tuple(p))
            grads = out[1:]
            p = [w - 0.5 * g for w, g in zip(p, grads)]
        l1 = float(model.loss_fn(CFG, p, toks))
        assert l1 < l0 * 0.7, (l0, l1)


class TestGradStep:
    def test_output_arity_and_shapes(self, params, tokens):
        out = model.grad_step(CFG, params, tokens)
        specs = model.param_specs(CFG)
        assert len(out) == 1 + len(specs)
        assert out[0].shape == ()
        for g, (_, shape, _, _) in zip(out[1:], specs):
            assert g.shape == shape

    def test_grad_matches_finite_differences(self, params, tokens):
        """Directional finite-difference check of the full fwd+bwd stack."""
        out = model.grad_step(CFG, params, tokens)
        grads = out[1:]
        key = jax.random.PRNGKey(42)
        dirs = [jax.random.normal(jax.random.fold_in(key, i), p.shape)
                for i, p in enumerate(params)]
        eps = 1e-3
        plus = [p + eps * d for p, d in zip(params, dirs)]
        minus = [p - eps * d for p, d in zip(params, dirs)]
        fd = (float(model.loss_fn(CFG, plus, tokens))
              - float(model.loss_fn(CFG, minus, tokens))) / (2 * eps)
        analytic = sum(float(jnp.vdot(g, d)) for g, d in zip(grads, dirs))
        assert abs(fd - analytic) < 5e-2 * max(1.0, abs(analytic)), \
            (fd, analytic)

    def test_grad_accumulation_equals_big_batch(self, params):
        """mean of micro-batch grads == grad of concatenated batch.

        This is the identity DropCompute relies on: the surviving
        micro-batches of a step average to an unbiased gradient.
        """
        key = jax.random.PRNGKey(3)
        t1 = jax.random.randint(key, (CFG.micro_batch, CFG.seq_len), 0,
                                CFG.vocab)
        t2 = jax.random.randint(jax.random.fold_in(key, 1),
                                (CFG.micro_batch, CFG.seq_len), 0, CFG.vocab)
        g1 = model.grad_step(CFG, params, t1)[1:]
        g2 = model.grad_step(CFG, params, t2)[1:]
        gbig = model.grad_step(CFG, params, jnp.concatenate([t1, t2]))[1:]
        for a, b, big in zip(g1, g2, gbig):
            np.testing.assert_allclose((a + b) / 2, big, rtol=2e-4, atol=2e-5)


class TestParamSpecs:
    def test_spec_count_matches_init(self, params):
        assert len(model.param_specs(CFG)) == len(params)

    @pytest.mark.parametrize("size", ["test", "tiny", "small", "base"])
    def test_param_count_positive(self, size):
        cfg = model.CONFIGS[size]
        assert model.param_count(cfg) > 0
        assert model.flops_per_microbatch(cfg) > model.param_count(cfg)

    def test_names_unique(self):
        names = [n for n, *_ in model.param_specs(model.CONFIGS["small"])]
        assert len(names) == len(set(names))
