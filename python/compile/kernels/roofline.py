"""L1 perf analysis: VMEM footprint + MXU utilization per BlockSpec.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the
kernel performance pass optimizes *structure*: keep the working set
inside VMEM (~16 MiB/core), maximize MXU tile occupancy, and maximize
arithmetic intensity (FLOPs per HBM byte). This module scores candidate
block shapes and picks the best; DESIGN.md §Perf records the outcome.

Run:  python -m compile.kernels.roofline
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

from . import attention

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget (TPU-like)
MXU = 128


@dataclasses.dataclass
class BlockScore:
    block_q: int
    block_k: int
    vmem_bytes: int
    mxu_utilization: float
    arithmetic_intensity: float  # flops per HBM byte
    fits: bool

    def figure_of_merit(self) -> float:
        """Higher is better; infeasible shapes are disqualified."""
        if not self.fits:
            return 0.0
        # utilization dominates; intensity breaks ties (log-scaled).
        return self.mxu_utilization * math.log2(1.0 + self.arithmetic_intensity)


def attention_hbm_bytes(seq_len: int, head_dim: int, block_q: int,
                        dtype_bytes: int = 4) -> int:
    """HBM traffic per (bh) for the flash schedule: Q/O once, K/V once per
    q-block (streamed)."""
    n_q_blocks = math.ceil(seq_len / block_q)
    qo = 2 * seq_len * head_dim
    kv = 2 * seq_len * head_dim * n_q_blocks
    return dtype_bytes * (qo + kv)


def attention_flops(seq_len: int, head_dim: int) -> int:
    """2 matmuls over the (seq, seq) score matrix per bh (causal ~1/2)."""
    return 2 * 2 * seq_len * seq_len * head_dim // 2


def score(seq_len: int, head_dim: int, block_q: int, block_k: int) -> BlockScore:
    vmem = attention.vmem_bytes(block_q, block_k, seq_len, head_dim)
    util = attention.mxu_utilization_estimate(block_q, block_k, head_dim)
    hbm = attention_hbm_bytes(seq_len, head_dim, block_q)
    flops = attention_flops(seq_len, head_dim)
    return BlockScore(
        block_q=block_q,
        block_k=block_k,
        vmem_bytes=vmem,
        mxu_utilization=util,
        arithmetic_intensity=flops / hbm,
        fits=vmem <= VMEM_BYTES,
    )


def sweep(seq_len: int, head_dim: int,
          candidates=(32, 64, 128, 256)) -> List[BlockScore]:
    out = []
    for bq in candidates:
        for bk in candidates:
            if bq > seq_len or bk > seq_len:
                continue
            out.append(score(seq_len, head_dim, bq, bk))
    return sorted(out, key=BlockScore.figure_of_merit, reverse=True)


def main() -> None:
    for (seq, hd) in [(128, 64), (512, 64), (2048, 128)]:
        print(f"\nattention seq={seq} head_dim={hd}  (VMEM budget 16 MiB)")
        print(f"{'bq':>5} {'bk':>5} {'VMEM KiB':>9} {'MXU util':>9} "
              f"{'AI flop/B':>10} {'fits':>5} {'FoM':>7}")
        for s in sweep(seq, hd)[:6]:
            print(f"{s.block_q:>5} {s.block_k:>5} "
                  f"{s.vmem_bytes / 1024:>9.0f} {s.mxu_utilization:>9.2f} "
                  f"{s.arithmetic_intensity:>10.1f} {str(s.fits):>5} "
                  f"{s.figure_of_merit():>7.3f}")
        best = sweep(seq, hd)[0]
        print(f"best: block_q={best.block_q} block_k={best.block_k}")


if __name__ == "__main__":
    main()
