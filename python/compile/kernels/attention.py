"""L1 Pallas kernel: causal flash attention (forward).

The paper's compute hot-spot for the BERT/transformer workloads is the
attention block. The original system targets Habana Gaudi (MME systolic
array + SRAM scratchpad); we re-think the kernel for the TPU model that
Pallas exposes:

* HBM <-> VMEM staging is expressed with ``BlockSpec``: queries are tiled
  into ``(1, block_q, head_dim)`` VMEM blocks over a ``(batch*heads,
  num_q_blocks)`` grid, keys/values are streamed through the kernel in
  ``block_k`` chunks with an online-softmax accumulator — the classic
  flash-attention schedule, which on a real TPU keeps the working set in
  VMEM and feeds the MXU with ``(block_q, head_dim) x (head_dim, block_k)``
  matmuls.
* On this image Pallas must run with ``interpret=True`` (the CPU PJRT
  plugin cannot execute Mosaic custom-calls), so the kernel lowers to plain
  HLO. Correctness is asserted against the pure-jnp oracle in ``ref.py``;
  the TPU performance analysis (VMEM footprint / MXU utilisation per block
  shape) lives in ``DESIGN.md`` and ``python/compile/kernels/roofline.py``.

The backward pass is provided via ``jax.custom_vjp`` using the reference
implementation's VJP: numerics match the kernel (same math), and the
combined fwd+bwd lowers into a single HLO module for the Rust runtime.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float,
                  causal: bool, block_q: int, seq_len: int,
                  padded_k_len: int):
    """One (batch*head, q-block) cell of the flash-attention grid.

    q_ref: (1, block_q, d) VMEM block of queries.
    k_ref/v_ref: (1, seq_len, d) — streamed in ``block_k`` slices.
    o_ref: (1, block_q, d) output block.
    """
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (bq, d)
    q_offset = pl.program_id(1) * block_q

    num_k_blocks = padded_k_len // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_offset = kb * block_k
        k = k_ref[0, pl.dslice(k_offset, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(k_offset, block_k), :].astype(jnp.float32)

        s = q @ k.T  # (bq, bk) — MXU matmul on real hardware
        # Mask keys past seq_len: pl.dslice clamps an out-of-bounds start
        # (dynamic_slice semantics), so the final partial block re-reads
        # earlier keys — they must carry zero attention weight.
        k_ids = k_offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_ids < seq_len
        if causal:
            q_ids = q_offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid = valid & (q_ids >= k_ids)
        s = jnp.where(valid, s, -jnp.inf)

        # Online softmax update (numerically stable streaming max/sum).
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows: exp(-inf - -inf) -> exp(0); correct via l.
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(jnp.isneginf(m_prev) & jnp.isneginf(m_new), 0.0, alpha)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    if causal:
        # Blocks strictly above the diagonal contribute nothing; skip them.
        last_block = jnp.minimum(
            num_k_blocks, (q_offset + block_q + block_k - 1) // block_k
        )
    else:
        last_block = num_k_blocks

    init = (
        jnp.zeros((q.shape[0], v_ref.shape[-1]), jnp.float32),
        jnp.full((q.shape[0],), -jnp.inf, jnp.float32),
        jnp.zeros((q.shape[0],), jnp.float32),
    )
    acc, _, l = jax.lax.fori_loop(0, last_block, body, init)
    # Rows that saw no unmasked key (cannot happen for causal q>=0) get 0.
    denom = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True) -> jax.Array:
    """Flash attention forward over ``(bh, seq, head_dim)`` tensors."""
    bh, seq_len, head_dim = q.shape
    block_q = min(block_q, seq_len)
    block_k = min(block_k, seq_len)

    # Pad sequence to block multiples so every pl.dslice is in-bounds
    # (dynamic_slice clamps OOB starts, which would misalign the final
    # partial block); padded key positions are masked to -inf in-kernel.
    pad_q = (-seq_len) % block_q
    pad_k = (-seq_len) % block_k
    pad = max(pad_q, pad_k)
    if pad:
        zeros = jnp.zeros((bh, pad, head_dim), q.dtype)
        qp = jnp.concatenate([q, zeros[:, :pad_q]], axis=1)
        kp = jnp.concatenate([k, zeros[:, :pad_k]], axis=1)
        vp = jnp.concatenate([v, zeros[:, :pad_k]], axis=1)
    else:
        qp, kp, vp = q, k, v
    padded_q_len = seq_len + pad_q
    padded_k_len = seq_len + pad_k

    sm_scale = 1.0 / math.sqrt(head_dim)
    grid = (bh, padded_q_len // block_q)

    kernel = functools.partial(
        _flash_kernel, block_k=block_k, sm_scale=sm_scale, causal=causal,
        block_q=block_q, seq_len=seq_len, padded_k_len=padded_k_len)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, padded_k_len, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, padded_k_len, head_dim), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :seq_len, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal=True):
    """Causal attention: Pallas kernel forward, reference VJP backward."""
    return flash_attention_fwd(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return flash_attention_fwd(q, k, v, causal=causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention(q, k, v, causal=causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def vmem_bytes(block_q: int, block_k: int, seq_len: int, head_dim: int,
               dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid cell (perf-pass metric).

    q block + streamed k/v chunks (double-buffered) + accumulator + output.
    """
    q_blk = block_q * head_dim
    kv_blk = 2 * 2 * block_k * head_dim  # k+v, double buffered
    acc = block_q * head_dim + 2 * block_q  # acc + m + l (f32)
    out = block_q * head_dim
    scores = block_q * block_k
    return dtype_bytes * (q_blk + kv_blk + acc + out + scores)


def mxu_utilization_estimate(block_q: int, block_k: int, head_dim: int,
                             mxu: int = 128) -> float:
    """Fraction of MXU lanes occupied by the two kernel matmuls.

    A (m,k)x(k,n) matmul tiles the 128x128 systolic array in ceil(m/128)*
    ceil(n/128) passes; utilization is the filled fraction of those tiles.
    """
    def util(m, n):
        tiles = math.ceil(m / mxu) * math.ceil(n / mxu)
        return (m * n) / (tiles * mxu * mxu)

    # s = q@k.T : (bq, d)x(d, bk);  o = p@v : (bq, bk)x(bk, d)
    return 0.5 * (util(block_q, block_k) + util(block_q, head_dim))
