"""L1 Pallas kernel: fused LayerNorm (forward).

Fuses mean/variance/normalize/affine into a single VMEM-resident pass over
``block_rows`` rows at a time — on TPU this avoids three HBM round-trips of
the unfused lowering. Backward is the reference VJP via ``custom_vjp``
(see attention.py for the rationale); ``interpret=True`` on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_ROWS = 128


def _ln_kernel(x_ref, scale_ref, bias_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...] + bias_ref[...]).astype(o_ref.dtype)


def layernorm_fwd(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                  eps: float = 1e-5,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True) -> jax.Array:
    """Fused LayerNorm over the last axis of ``(rows, dim)``."""
    rows, dim = x.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale, bias)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def layernorm(x, scale, bias):
    """Fused LayerNorm: Pallas forward, reference VJP backward."""
    return layernorm_fwd(x, scale, bias)


def _fwd(x, scale, bias):
    return layernorm_fwd(x, scale, bias), (x, scale, bias)


def _bwd(res, g):
    x, scale, bias = res
    _, vjp = jax.vjp(ref.layernorm, x, scale, bias)
    return vjp(g)


layernorm.defvjp(_fwd, _bwd)


def vmem_bytes(block_rows: int, dim: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid cell (x block + out block + affine)."""
    return dtype_bytes * (2 * block_rows * dim + 2 * dim + 2 * block_rows)
