"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel in this package must
match its oracle to float32 tolerance across the shape/dtype sweep in
``python/tests/``. They are also the backward-pass implementations used by
the kernels' ``custom_vjp`` (see attention.py), so fwd/bwd numerics agree
by construction.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True) -> jnp.ndarray:
    """Scaled dot-product attention over ``(bh, seq, head_dim)``."""
    _, seq_len, head_dim = q.shape
    scale = 1.0 / math.sqrt(head_dim)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq_len, seq_len), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v).astype(q.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias
