"""L2 perf analysis: op statistics of the lowered HLO modules.

The L2 target is structural: no redundant recomputation, fusable
elementwise chains, no gratuitous transposes/copies. This tool counts op
categories in the emitted HLO text so regressions show up as diffs in
`make artifacts` output and in EXPERIMENTS.md §Perf.

Run:  python -m compile.hlo_stats ../artifacts/small/grad.hlo.txt
"""

from __future__ import annotations

import re
import sys
from collections import Counter


OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s/]*?\s(\w+)\(")

CATEGORIES = {
    "dot": "matmul",
    "convolution": "matmul",
    "transpose": "layout",
    "copy": "layout",
    "reshape": "layout",
    "broadcast": "layout",
    "exponential": "elementwise",
    "add": "elementwise",
    "multiply": "elementwise",
    "divide": "elementwise",
    "subtract": "elementwise",
    "maximum": "elementwise",
    "rsqrt": "elementwise",
    "tanh": "elementwise",
    "reduce": "reduce",
    "scatter": "scatter",
    "gather": "gather",
    "dynamic-slice": "slice",
    "dynamic-update-slice": "slice",
    "while": "control",
    "conditional": "control",
    "fusion": "fusion",
    "custom-call": "custom-call",
}


def stats(text: str) -> Counter:
    ops = Counter()
    for line in text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def categorize(ops: Counter) -> Counter:
    cats = Counter()
    for op, n in ops.items():
        cats[CATEGORIES.get(op, "other")] += n
    return cats


def report(path: str) -> dict:
    with open(path) as f:
        text = f.read()
    ops = stats(text)
    cats = categorize(ops)
    total = sum(ops.values())
    dots = ops.get("dot", 0)
    layout = cats.get("layout", 0)
    out = {
        "total_ops": total,
        "dot": dots,
        "layout_ops": layout,
        "layout_fraction": layout / max(total, 1),
        "custom_calls": ops.get("custom-call", 0),
        "while_loops": ops.get("while", 0),
        "top": ops.most_common(8),
    }
    return out


def main() -> None:
    for path in sys.argv[1:] or ["../artifacts/small/grad.hlo.txt"]:
        r = report(path)
        print(f"\n{path}")
        print(f"  total ops      : {r['total_ops']}")
        print(f"  dot (matmul)   : {r['dot']}")
        print(f"  layout ops     : {r['layout_ops']} "
              f"({100 * r['layout_fraction']:.1f}%)")
        print(f"  custom-calls   : {r['custom_calls']} (must be 0 on CPU)")
        print(f"  while loops    : {r['while_loops']}")
        print(f"  top ops        : {r['top']}")


if __name__ == "__main__":
    main()
