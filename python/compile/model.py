"""L2: the training workload — a decoder-only transformer LM in JAX.

This is the per-worker compute of the paper's data-parallel setup: one
micro-batch forward+backward (``grad_step``) is the unit the DropCompute
coordinator schedules ``M`` times per step per worker (Algorithm 1, line 5).
The attention and LayerNorm blocks call the Pallas kernels from
``kernels/``; everything is lowered by ``aot.py`` into a single HLO module
per entry point, loaded and executed by the Rust runtime.

Parameters are carried as a *flat list* of arrays in a deterministic order
(see ``param_specs``) so the Rust side can marshal them without a pytree
library. Initialization is performed Rust-side from the ``init`` hints in
the manifest (python never runs at training time).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import layernorm as ln_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters for one artifact size."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    micro_batch: int
    d_ff: int = 0  # 0 -> 4*d_model
    use_pallas: bool = True

    @property
    def ff(self) -> int:
        return self.d_ff or 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Artifact sizes. `test` is for pytest; `base`+ for the e2e driver.
CONFIGS = {
    "test": ModelConfig("test", vocab=64, d_model=32, n_layers=2, n_heads=2,
                        seq_len=16, micro_batch=2),
    "tiny": ModelConfig("tiny", vocab=512, d_model=64, n_layers=2, n_heads=4,
                        seq_len=32, micro_batch=4),
    "small": ModelConfig("small", vocab=2048, d_model=128, n_layers=4,
                         n_heads=4, seq_len=64, micro_batch=8),
    "base": ModelConfig("base", vocab=8192, d_model=256, n_layers=6,
                        n_heads=8, seq_len=128, micro_batch=8),
    # ~33M params: the e2e pretraining workload ("BERT-class" stand-in).
    "large": ModelConfig("large", vocab=16384, d_model=512, n_layers=8,
                         n_heads=8, seq_len=128, micro_batch=8),
    # ~110M params: matches the paper-scale 100M-parameter ask; artifact
    # builds fine, CPU execution is for short smoke runs.
    "xl": ModelConfig("xl", vocab=32768, d_model=768, n_layers=12,
                      n_heads=12, seq_len=128, micro_batch=4),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str, float]]:
    """Deterministic flat parameter order: (name, shape, init, init_scale).

    init ∈ {"normal", "zeros", "ones"}; scale is the stddev for "normal".
    The Rust side reproduces this exactly (see rust/src/train/params.rs).
    """
    d, f = cfg.d_model, cfg.ff
    specs: List[Tuple[str, Tuple[int, ...], str, float]] = [
        ("tok_embed", (cfg.vocab, d), "normal", 0.02),
        ("pos_embed", (cfg.seq_len, d), "normal", 0.01),
    ]
    resid_scale = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.scale", (d,), "ones", 0.0),
            (p + "ln1.bias", (d,), "zeros", 0.0),
            (p + "attn.wq", (d, d), "normal", 0.02),
            (p + "attn.wk", (d, d), "normal", 0.02),
            (p + "attn.wv", (d, d), "normal", 0.02),
            (p + "attn.wo", (d, d), "normal", resid_scale),
            (p + "ln2.scale", (d,), "ones", 0.0),
            (p + "ln2.bias", (d,), "zeros", 0.0),
            (p + "mlp.w1", (d, f), "normal", 0.02),
            (p + "mlp.b1", (f,), "zeros", 0.0),
            (p + "mlp.w2", (f, d), "normal", resid_scale),
            (p + "mlp.b2", (d,), "zeros", 0.0),
        ]
    specs += [
        ("ln_f.scale", (d,), "ones", 0.0),
        ("ln_f.bias", (d,), "zeros", 0.0),
    ]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for _, s, _, _ in param_specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """Reference initializer (used by tests; Rust re-implements it)."""
    params = []
    for _, shape, kind, scale in param_specs(cfg):
        key, sub = jax.random.split(key)
        if kind == "normal":
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
        elif kind == "zeros":
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            params.append(jnp.ones(shape, jnp.float32))
    return params


def _layernorm(cfg, x2d, scale, bias):
    if cfg.use_pallas:
        return ln_k.layernorm(x2d, scale, bias)
    from .kernels import ref
    return ref.layernorm(x2d, scale, bias)


def _attention(cfg, q, k, v):
    if cfg.use_pallas:
        return attn_k.flash_attention(q, k, v, True)
    from .kernels import ref
    return ref.attention(q, k, v, causal=True)


def forward(cfg: ModelConfig, params: List[jax.Array],
            tokens: jax.Array) -> jax.Array:
    """Logits ``(B, S, vocab)`` for int32 ``tokens (B, S)``."""
    it = iter(params)

    def take():
        return next(it)

    tok_embed, pos_embed = take(), take()
    b, s = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    x = tok_embed[tokens] + pos_embed[None, :s, :]

    for _ in range(cfg.n_layers):
        ln1s, ln1b = take(), take()
        wq, wk, wv, wo = take(), take(), take(), take()
        ln2s, ln2b = take(), take()
        w1, b1, w2, b2 = take(), take(), take(), take()

        hflat = _layernorm(cfg, x.reshape(b * s, d), ln1s, ln1b)
        hx = hflat.reshape(b, s, d)

        def heads(t):  # (b, s, d) -> (b*h, s, hd)
            return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3).reshape(b * h, s, hd)

        q, k, v = heads(hx @ wq), heads(hx @ wk), heads(hx @ wv)
        o = _attention(cfg, q, k, v)
        o = o.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ wo

        hflat = _layernorm(cfg, x.reshape(b * s, d), ln2s, ln2b)
        hx = hflat.reshape(b, s, d)
        x = x + (jax.nn.gelu(hx @ w1 + b1) @ w2 + b2)

    lnfs, lnfb = take(), take()
    x = _layernorm(cfg, x.reshape(b * s, d), lnfs, lnfb).reshape(b, s, d)
    # Tied LM head (weight sharing with the token embedding).
    return x @ tok_embed.T


def loss_fn(cfg: ModelConfig, params: List[jax.Array],
            tokens: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; last position has no target."""
    logits = forward(cfg, params, tokens)  # (B, S, V)
    logits = logits[:, :-1, :]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def grad_step(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array):
    """One micro-batch: returns ``(loss, *grads)`` — the AOT entry point."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    return (loss, *grads)


def flops_per_microbatch(cfg: ModelConfig) -> int:
    """~6 * params * tokens for fwd+bwd (standard transformer estimate)."""
    return 6 * param_count(cfg) * cfg.micro_batch * cfg.seq_len
