"""AOT pipeline: lower the L2/L1 stack to HLO *text* artifacts.

Python runs once, here; Rust loads the artifacts and never calls back.

Interchange format is HLO TEXT (not serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Per model size this emits into ``artifacts/<size>/``:
  grad.hlo.txt   — (params..., tokens[B,S] i32) -> (loss, grads...)
  loss.hlo.txt   — (params..., tokens[B,S] i32) -> (loss,)
  manifest.json  — shapes, param specs + init hints, flop estimate

Usage: python -m compile.aot --out-dir ../artifacts --sizes test,tiny,...
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_size(cfg: model.ModelConfig, out_dir: str) -> dict:
    """Lower grad + loss entry points for one size; return manifest entry."""
    specs = model.param_specs(cfg)
    param_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _, _ in specs]
    tok_shape = jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq_len), jnp.int32)

    os.makedirs(out_dir, exist_ok=True)

    def grad_fn(params, tokens):
        return model.grad_step(cfg, list(params), tokens)

    def loss_fn(params, tokens):
        return (model.loss_fn(cfg, list(params), tokens),)

    for name, fn in [("grad", grad_fn), ("loss", loss_fn)]:
        lowered = jax.jit(fn).lower(tuple(param_shapes), tok_shape)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")

    return {
        "name": cfg.name,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len, "micro_batch": cfg.micro_batch,
            "d_ff": cfg.ff,
        },
        "param_count": model.param_count(cfg),
        "flops_per_microbatch": model.flops_per_microbatch(cfg),
        "params": [
            {"name": n, "shape": list(s), "init": k, "scale": sc}
            for n, s, k, sc in specs
        ],
        "inputs": {"tokens": [cfg.micro_batch, cfg.seq_len]},
        "entrypoints": {
            "grad": {"file": "grad.hlo.txt",
                     "outputs": ["loss"] + [n for n, *_ in specs]},
            "loss": {"file": "loss.hlo.txt", "outputs": ["loss"]},
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="test,tiny,small,base,large")
    args = ap.parse_args()

    for size in args.sizes.split(","):
        size = size.strip()
        cfg = model.CONFIGS[size]
        print(f"lowering size={size} "
              f"(params={model.param_count(cfg) / 1e6:.2f}M)")
        entry = lower_size(cfg, os.path.join(args.out_dir, size))
        with open(os.path.join(args.out_dir, size, "manifest.json"), "w") as f:
            json.dump(entry, f, indent=1)
    print("AOT done.")


if __name__ == "__main__":
    main()
